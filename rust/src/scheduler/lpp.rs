//! LP formulations of token scheduling: LPP 1 (§5.1), LPP 4 and its
//! topology-aware refinement (Appendix A.1), plus [`MicroEpScheduler`],
//! the stateful per-micro-batch solver with warm start.
//!
//! Variable/row layouts are fixed at construction (the placement determines
//! the constraint matrix); each micro-batch only rewrites rhs entries and
//! variable upper bounds — exactly the property that makes warm starting
//! effective. The per-replica caps (`l_e^g ≤ input_e^g`, and the node
//! aggregates `n_e^ν ≤ node_input_e^ν`) are emitted as *variable bounds*,
//! not rows: the default revised-simplex backend enforces them implicitly,
//! shrinking the row count `m` by ~`nx` (CommAware) / ~`2·nx` (TopoAware).
//! The dense-tableau backend (kept for the `ablation_solvers` bench via
//! [`crate::lp::SolverKind::DenseTableau`]) lowers the same bounds back
//! into rows, so both backends solve identical problems. Within the
//! revised backend, [`crate::scheduler::SchedulerOptions::solver`] further
//! selects the pricing rule ([`crate::lp::Pricing`]) and the basis
//! factorization ([`crate::lp::FactorKind`]); the default — devex with an
//! automatic dense-inverse/sparse-LU cut — is what keeps the solve under
//! the ~1 ms budget past 128 GPUs.
//!
//! One deliberate deviation from the paper's Appendix A.1 formulas: the
//! paper's `send_g` sums only over experts *resident* on g; physically a
//! GPU also sends every token destined to a non-resident expert, so we use
//! `send_g = total_input_g − local_g` (total over all experts). The
//! difference is a per-GPU constant inside the `max`, and the physical
//! version is what our cluster model charges for, so we optimize that.

use std::time::Instant;

use super::decompose;
use super::fallback;
use super::rounding::round_replica_loads;
use super::routing::route_tokens;
use super::{LoadMatrix, Schedule, ScheduleMode, ScheduleStats, SchedulerOptions};
use crate::lp::{LpProblem, Relation, SimplexError, SolveBudget, SolveStats, WarmSolver};
use crate::placement::Placement;
use crate::stats::DegradationRung;
use crate::topology::Topology;

/// Largest magnitude accepted into the LP's rhs/bound updates. Token
/// counts live far below this; anything beyond (or non-finite) marks a
/// corrupted load matrix, and the solve is skipped in favor of the greedy
/// fallback rather than feeding the simplex ratio tests garbage.
const MAX_LP_LOAD: f64 = 9.0e15;

/// Stateful MicroEP scheduler for one MicroEP group.
pub struct MicroEpScheduler {
    /// The expert placement this scheduler's constraint matrix was built
    /// from (fixed for the scheduler's lifetime — §5.1).
    pub placement: Placement,
    topo: Option<Topology>,
    opts: SchedulerOptions,
    /// x-variable index per (expert, replica)
    var_of: Vec<Vec<usize>>,
    /// Eq-row index per expert (rhs = load_e)
    eq_row: Vec<usize>,
    /// variables whose upper bound is `input_e^g` (CommAware/TopoAware):
    /// (var, e, g)
    input_cap_vars: Vec<(usize, usize, usize)>,
    /// rows whose rhs is `-total_input_g`: (row, g)
    send_rows: Vec<(usize, usize)>,
    /// variables whose upper bound is node-aggregated input
    /// `node_input_e^n`: (var, e, node)
    node_cap_vars: Vec<(usize, usize, usize)>,
    /// rows whose rhs is `-total node input`: (row, node)
    node_send_rows: Vec<(usize, usize)>,
    /// per-GPU `Σx − t ≤ −base_g` rows (Compute mode): (row, gpu); rhs 0
    /// normally, −base when pipelining adds a fixed EP load (App. A.2)
    gpu_rows: Vec<(usize, usize)>,
    /// transient rhs overrides installed by [`Self::schedule_with_base`]
    base_updates: Vec<(usize, f64)>,
    /// whether a nonzero base rhs is (or may still be) installed in the
    /// warm solver's `gpu_rows` — lets the common no-base path skip the
    /// per-batch zero-reset of those rows entirely
    gpu_rows_dirty: bool,
    warm: WarmSolver,
    /// Two-level solver state when `opts.mode` is
    /// [`ScheduleMode::Decomposed`]; the monolithic `warm` solver then
    /// holds only a placeholder problem and is never consulted.
    decomp: Option<decompose::DecomposedState>,
    solved_once: bool,
    /// Layer id used for fault-plan lookups (engine workers pin one
    /// scheduler per layer; standalone schedulers keep the default 0).
    layer: usize,
    /// Next commit step for fault-plan lookups. Advances on every commit
    /// solve; the engine overrides it per job ([`Self::schedule_at`]) so
    /// the count survives worker respawns.
    step: usize,
}

impl MicroEpScheduler {
    /// Build the scheduler: lowers the placement into the LP constraint
    /// matrix for `opts.mode` once; every later [`Self::schedule`] call
    /// only rewrites rhs entries and variable bounds.
    pub fn new(placement: Placement, topo: Option<Topology>, opts: SchedulerOptions) -> Self {
        if matches!(
            opts.mode,
            ScheduleMode::TopoAware { .. } | ScheduleMode::Decomposed { .. }
        ) || opts.topo_aware_routing
        {
            assert!(topo.is_some(), "topology-aware scheduling needs a Topology");
        }
        let mut b = Builder::new(&placement, topo.as_ref(), &opts.mode);
        let problem = b.build();
        let mut warm = WarmSolver::with_kind(problem, opts.solver);
        warm.set_budget(opts.budget);
        let decomp = if let ScheduleMode::Decomposed { nodes_per_block, max_outer_iters, tol } =
            &opts.mode
        {
            Some(decompose::DecomposedState::new(
                &placement,
                topo.as_ref().unwrap(),
                &opts,
                *nodes_per_block,
                *max_outer_iters,
                *tol,
            ))
        } else {
            None
        };
        MicroEpScheduler {
            placement,
            topo,
            decomp,
            var_of: b.var_of,
            eq_row: b.eq_row,
            input_cap_vars: b.input_cap_vars,
            send_rows: b.send_rows,
            node_cap_vars: b.node_cap_vars,
            node_send_rows: b.node_send_rows,
            gpu_rows: b.gpu_rows,
            base_updates: Vec::new(),
            gpu_rows_dirty: false,
            warm,
            solved_once: false,
            layer: 0,
            step: 0,
            opts,
        }
    }

    /// Set the layer id used for fault-plan lookups
    /// ([`SchedulerOptions::faults`]). A no-op for fault-free schedulers.
    pub fn set_layer(&mut self, layer: usize) {
        self.layer = layer;
    }

    /// The options this scheduler was built with.
    pub fn options(&self) -> &SchedulerOptions {
        &self.opts
    }

    /// Schedule one micro-batch with pre-existing per-GPU base loads
    /// (App. A.2 pipelining: the EP-routed share is already fixed, the LP
    /// balances the MicroEP share around it). Compute mode only.
    pub fn schedule_with_base(&mut self, loads: &LoadMatrix, base: &[u64]) -> Schedule {
        assert!(
            matches!(self.opts.mode, ScheduleMode::Compute),
            "base loads are only supported in Compute mode"
        );
        assert_eq!(base.len(), self.placement.num_gpus);
        self.base_updates = self
            .gpu_rows
            .iter()
            .map(|&(row, g)| (row, -(base[g] as f64)))
            .collect();
        let sched = self.schedule(loads);
        self.base_updates.clear();
        sched
    }

    /// Schedule one micro-batch.
    pub fn schedule(&mut self, loads: &LoadMatrix) -> Schedule {
        let use_warm = self.opts.warm_start && self.solved_once;
        self.schedule_inner(loads, use_warm, true)
    }

    /// Commit-schedule at an explicit step index. The engine workers use
    /// this so the fault-plan step count is authoritative even when a
    /// respawned worker replays re-submitted jobs.
    pub fn schedule_at(&mut self, step: usize, loads: &LoadMatrix) -> Schedule {
        self.step = step;
        self.schedule(loads)
    }

    /// Cold commit-schedule at an explicit step index (speculation-miss
    /// path through the engine).
    pub fn schedule_cold_at(&mut self, step: usize, loads: &LoadMatrix) -> Schedule {
        self.step = step;
        self.schedule_cold(loads)
    }

    /// Speculative pre-solve: primes the warm-start basis exactly like
    /// [`Self::schedule`] but is *not* a committed step — the fault plan is
    /// not consulted and the step counter does not advance. (With no fault
    /// plan this is behaviorally identical to `schedule`.)
    pub fn speculate(&mut self, loads: &LoadMatrix) -> Schedule {
        let use_warm = self.opts.warm_start && self.solved_once;
        self.schedule_inner(loads, use_warm, false)
    }

    /// Schedule one micro-batch from scratch, ignoring (and replacing) any
    /// retained warm-start basis. The engine's speculation path uses this
    /// when a forecast missed: the speculatively primed basis is too far
    /// from the actuals to be worth repairing, and a fresh solve both
    /// bounds the commit latency and re-anchors the warm state.
    pub fn schedule_cold(&mut self, loads: &LoadMatrix) -> Schedule {
        self.schedule_inner(loads, false, true)
    }

    /// Per-GPU base loads implied by the transient `base_updates` rhs
    /// overrides (empty when no base is installed) — lets the greedy
    /// fallback account for the App. A.2 pipelined EP share too.
    fn base_loads(&self) -> Vec<u64> {
        if self.base_updates.is_empty() {
            return Vec::new();
        }
        let mut base = vec![0u64; self.placement.num_gpus];
        for (&(_, g), &(_, rhs)) in self.gpu_rows.iter().zip(&self.base_updates) {
            base[g] = (-rhs) as u64;
        }
        base
    }

    fn schedule_inner(&mut self, loads: &LoadMatrix, use_warm: bool, commit: bool) -> Schedule {
        assert_eq!(loads.num_experts, self.placement.num_experts);
        assert_eq!(loads.num_gpus, self.placement.num_gpus);
        if self.decomp.is_some() {
            return self.schedule_decomposed(loads, use_warm, commit);
        }
        let t0 = Instant::now();
        // the commit step this solve belongs to (self.step advances in the
        // fault block below; spans must report the pre-increment index)
        let span_step = self.step;

        // ---- rhs + bound updates for this micro-batch ----
        let mut updates: Vec<(usize, f64)> = Vec::with_capacity(
            self.gpu_rows.len().max(self.base_updates.len())
                + self.eq_row.len()
                + self.send_rows.len()
                + self.node_send_rows.len(),
        );
        let mut bound_updates: Vec<(usize, f64)> =
            Vec::with_capacity(self.input_cap_vars.len() + self.node_cap_vars.len());
        // gpu rows: −base when pipelining; reset to 0 only if a base was
        // ever installed (the rhs persists inside the warm solver between
        // calls, and starts at 0 — the common path skips the reset)
        if !self.base_updates.is_empty() {
            updates.extend(self.base_updates.iter().copied());
            self.gpu_rows_dirty = true;
        } else if self.gpu_rows_dirty {
            updates.extend(self.gpu_rows.iter().map(|&(row, _)| (row, 0.0)));
            self.gpu_rows_dirty = false;
        }
        for e in 0..self.placement.num_experts {
            updates.push((self.eq_row[e], loads.expert_load(e) as f64));
        }
        for &(var, e, g) in &self.input_cap_vars {
            bound_updates.push((var, loads.get(e, g) as f64));
        }
        for &(row, g) in &self.send_rows {
            updates.push((row, -(loads.gpu_input(g) as f64)));
        }
        if !self.node_cap_vars.is_empty() || !self.node_send_rows.is_empty() {
            let topo = self.topo.as_ref().unwrap();
            let nodes = self.placement.num_gpus.div_ceil(topo.gpus_per_node);
            // node-aggregated inputs per expert
            let mut node_in = vec![vec![0u64; nodes]; self.placement.num_experts];
            let mut node_total = vec![0u64; nodes];
            for g in 0..self.placement.num_gpus {
                let n = topo.node_of(g);
                for e in 0..self.placement.num_experts {
                    node_in[e][n] += loads.get(e, g);
                }
                node_total[n] += loads.gpu_input(g);
            }
            for &(var, e, n) in &self.node_cap_vars {
                bound_updates.push((var, node_in[e][n] as f64));
            }
            for &(row, n) in &self.node_send_rows {
                updates.push((row, -(node_total[n] as f64)));
            }
        }

        // ---- fault injection (chaos harness; `faults` is None outside it) ----
        let fault = if commit {
            let f = self.opts.faults.as_ref().and_then(|f| f.at(self.step, self.layer));
            self.step += 1;
            f
        } else {
            None
        };
        let mut starved = false;
        match fault {
            Some(crate::faults::Fault::BudgetStarvation) => starved = true,
            Some(crate::faults::Fault::NanLoads) => {
                if let Some(u) = updates.first_mut() {
                    u.1 = f64::NAN;
                }
            }
            Some(crate::faults::Fault::OverflowLoads) => {
                if let Some(u) = updates.first_mut() {
                    u.1 = 1e300;
                }
            }
            Some(crate::faults::Fault::ForceInfeasible) => {
                // Σ x_e = −1 with x ≥ 0 is unsatisfiable in every mode
                if let Some(&row) = self.eq_row.first() {
                    if let Some(u) = updates.iter_mut().find(|u| u.0 == row) {
                        u.1 = -1.0;
                    }
                }
            }
            // worker panics are the engine pool's business, not ours
            _ => {}
        }

        // ---- solve: rungs 0–2 of the degradation ladder ----
        // Rung 0 (warm LP) and rung 1 (cold LP, including the automatic
        // warm→cold fallback inside the solver) run only on validated
        // inputs; any failure drops to rung 2, the greedy water-fill,
        // which works from the true integer loads and cannot fail.
        let inputs_valid = updates.iter().all(|&(_, v)| v.is_finite() && v.abs() <= MAX_LP_LOAD)
            && bound_updates.iter().all(|&(_, v)| v.is_finite() && v.abs() <= MAX_LP_LOAD);
        if starved {
            self.warm.set_budget(SolveBudget::with_max_pivots(0));
        }
        let lp_result = if inputs_valid {
            Some(self.warm.solve_with_bounds(&updates, &bound_updates, use_warm))
        } else {
            log::warn!("corrupted LP inputs (non-finite or overflowing); using greedy fallback");
            None
        };
        if starved {
            self.warm.set_budget(self.opts.budget);
        }
        // a budget-exhausted *warm* attempt that fell through to a cold
        // solve still counts as a budget event (the ladder descended a rung)
        let mut budget_exhausted = match (&lp_result, &self.warm.last_warm_failure) {
            (Some(_), Some(SimplexError::BudgetExhausted(r))) => Some(*r),
            _ => None,
        };
        let (frac, stats_lp, rung, lower_bound) = match lp_result {
            Some(Ok(sol)) => {
                self.solved_once = true;
                let frac: Vec<Vec<f64>> = self
                    .var_of
                    .iter()
                    .map(|vars| vars.iter().map(|&v| sol.x[v]).collect())
                    .collect();
                let rung = if self.warm.last_was_warm {
                    DegradationRung::WarmLp
                } else {
                    DegradationRung::ColdLp
                };
                (frac, (self.warm.last_stats, self.warm.last_was_warm, sol.objective), rung, None)
            }
            other => {
                if let Some(Err(e)) = other {
                    if let SimplexError::BudgetExhausted(r) = &e {
                        budget_exhausted = Some(*r);
                    }
                    log::warn!("LP solve failed ({e}); degrading to greedy fallback");
                }
                let base = self.base_loads();
                let frac = fallback::greedy_fraction(&self.placement, loads, &base);
                let lower = fallback::lp_lower_bound(&self.placement, loads);
                (
                    frac,
                    (SolveStats::default(), false, f64::NAN),
                    DegradationRung::Greedy,
                    Some(lower),
                )
            }
        };

        // ---- integer rounding ----
        let replica_loads = round_replica_loads(&frac, &loads.expert_loads());

        // ---- token routing (Algorithm 1) ----
        let routes = route_tokens(
            &self.placement,
            loads,
            &replica_loads,
            self.opts.locality_aware,
            if self.opts.topo_aware_routing { self.topo.as_ref() } else { None },
        );

        let mut sched = Schedule {
            replica_loads,
            routes,
            stats: ScheduleStats {
                lp_iterations: stats_lp.0.pivots,
                lp_dual_pivots: stats_lp.0.dual_pivots,
                lp_bound_flips: stats_lp.0.bound_flips,
                lp_refactors: stats_lp.0.refactorizations,
                warm: stats_lp.1,
                lp_objective: stats_lp.2,
                max_gpu_load: 0,
                solve_ns: 0,
                rung,
                budget_exhausted,
                fallback_excess: 0.0,
                decompose: None,
            },
        };
        sched.stats.max_gpu_load = sched.gpu_loads(&self.placement).into_iter().max().unwrap_or(0);
        if let Some(lb) = lower_bound {
            sched.stats.fallback_excess = fallback::excess_over_bound(sched.stats.max_gpu_load, lb);
        }
        sched.stats.solve_ns = t0.elapsed().as_nanos() as u64;
        if commit {
            self.emit_solve_span(span_step, &sched.stats);
        }
        sched
    }

    /// Record one committed solve as a trace span (no-op when tracing is
    /// off). Gated on `commit` by the callers so solve-span rung counts
    /// match [`crate::stats::DegradationStats`] exactly.
    fn emit_solve_span(&self, step: usize, stats: &ScheduleStats) {
        self.opts.trace.record(
            stats.solve_ns as f64 / 1_000.0,
            crate::obs::Span::Solve {
                step,
                layer: self.layer,
                mode: self.opts.mode.name(),
                rung: stats.rung,
                warm: stats.warm,
                pivots: stats.lp_iterations,
                dual_pivots: stats.lp_dual_pivots,
                flips: stats.lp_bound_flips,
                refactors: stats.lp_refactors,
            },
        );
    }

    /// Decomposed-mode solve path ([`ScheduleMode::Decomposed`]): the
    /// two-level master/subproblem iteration in [`decompose`] replaces the
    /// monolithic LP; fault handling, rounding, routing, and stats mirror
    /// [`Self::schedule_inner`].
    fn schedule_decomposed(&mut self, loads: &LoadMatrix, use_warm: bool, commit: bool) -> Schedule {
        let t0 = Instant::now();
        let span_step = self.step;
        // decompose rounds are only traced for committed solves, matching
        // the solve-span gating (speculative probes leave no spans)
        let round_trace =
            if commit { self.opts.trace.clone() } else { crate::obs::Tracer::off() };

        // ---- fault injection (chaos harness; `faults` is None outside it) ----
        let fault = if commit {
            let f = self.opts.faults.as_ref().and_then(|f| f.at(self.step, self.layer));
            self.step += 1;
            f
        } else {
            None
        };
        // Corrupted loads and forced infeasibility have no single rhs to
        // poison here (each block sees its own slice), so they skip the
        // decomposition outright — the same ladder rung the monolithic
        // path lands on after its solver rejects the poisoned input.
        // Budget starvation instead starves every *block* budget: blocks
        // degrade individually and the layer answer is still assembled.
        let mut starved = false;
        let mut poisoned = false;
        match fault {
            Some(crate::faults::Fault::BudgetStarvation) => starved = true,
            Some(
                crate::faults::Fault::NanLoads
                | crate::faults::Fault::OverflowLoads
                | crate::faults::Fault::ForceInfeasible,
            ) => poisoned = true,
            _ => {}
        }
        let inputs_valid =
            !poisoned && loads.expert_loads().iter().all(|&l| (l as f64) <= MAX_LP_LOAD);

        let decomp = self.decomp.as_mut().expect("decomposed mode");
        let (frac, stats_lp, rung, budget_exhausted, lower_bound, meters) = if inputs_valid {
            if starved {
                decomp.set_budget(SolveBudget::with_max_pivots(0));
            }
            let s = decomp.solve(&self.placement, loads, use_warm, &round_trace);
            if starved {
                decomp.set_budget(self.opts.budget);
            }
            self.solved_once = true;
            let warm = s.rung == DegradationRung::WarmLp;
            // fallback_excess keeps its ladder meaning: distance to the
            // bound only when the layer as a whole degraded to greedy
            let lb = (s.rung == DegradationRung::Greedy).then_some(s.lower_bound);
            (s.frac, (s.lp, warm, s.objective), s.rung, s.budget_exhausted, lb, Some(s.meters))
        } else {
            log::warn!("corrupted LP inputs in decomposed mode; using greedy fallback");
            let frac = fallback::greedy_fraction(&self.placement, loads, &[]);
            let lower = fallback::lp_lower_bound(&self.placement, loads);
            (
                frac,
                (SolveStats::default(), false, f64::NAN),
                DegradationRung::Greedy,
                None,
                Some(lower),
                None,
            )
        };

        // ---- integer rounding + routing: identical to the global path ----
        let replica_loads = round_replica_loads(&frac, &loads.expert_loads());
        let routes = route_tokens(
            &self.placement,
            loads,
            &replica_loads,
            self.opts.locality_aware,
            if self.opts.topo_aware_routing { self.topo.as_ref() } else { None },
        );

        let mut sched = Schedule {
            replica_loads,
            routes,
            stats: ScheduleStats {
                lp_iterations: stats_lp.0.pivots,
                lp_dual_pivots: stats_lp.0.dual_pivots,
                lp_bound_flips: stats_lp.0.bound_flips,
                lp_refactors: stats_lp.0.refactorizations,
                warm: stats_lp.1,
                lp_objective: stats_lp.2,
                max_gpu_load: 0,
                solve_ns: 0,
                rung,
                budget_exhausted,
                fallback_excess: 0.0,
                decompose: meters,
            },
        };
        sched.stats.max_gpu_load = sched.gpu_loads(&self.placement).into_iter().max().unwrap_or(0);
        if let Some(lb) = lower_bound {
            sched.stats.fallback_excess = fallback::excess_over_bound(sched.stats.max_gpu_load, lb);
        }
        sched.stats.solve_ns = t0.elapsed().as_nanos() as u64;
        if commit {
            self.emit_solve_span(span_step, &sched.stats);
        }
        sched
    }
}

/// Constraint-matrix builder for the three LP modes.
struct Builder {
    var_of: Vec<Vec<usize>>,
    eq_row: Vec<usize>,
    input_cap_vars: Vec<(usize, usize, usize)>,
    send_rows: Vec<(usize, usize)>,
    node_cap_vars: Vec<(usize, usize, usize)>,
    node_send_rows: Vec<(usize, usize)>,
    gpu_rows: Vec<(usize, usize)>,
    problem: Option<LpProblem>,
}

impl Builder {
    fn new(p: &Placement, topo: Option<&Topology>, mode: &ScheduleMode) -> Self {
        let g_count = p.num_gpus;
        let e_count = p.num_experts;
        let nx: usize = (0..e_count).map(|e| p.replica_count(e)).sum();
        let mut var_of = Vec::with_capacity(e_count);
        let mut next = 0usize;
        for e in 0..e_count {
            let vars: Vec<usize> = (0..p.replica_count(e)).map(|r| next + r).collect();
            next += p.replica_count(e);
            var_of.push(vars);
        }
        debug_assert_eq!(next, nx);

        // per-GPU x-term lists: (gpu -> [(var)])
        let mut on_gpu: Vec<Vec<usize>> = vec![Vec::new(); g_count];
        for e in 0..e_count {
            for (r, &g) in p.replicas[e].iter().enumerate() {
                on_gpu[g].push(var_of[e][r]);
            }
        }

        let mut me = Builder {
            var_of,
            eq_row: Vec::new(),
            input_cap_vars: Vec::new(),
            send_rows: Vec::new(),
            node_cap_vars: Vec::new(),
            node_send_rows: Vec::new(),
            gpu_rows: Vec::new(),
            problem: None,
        };

        let problem = match mode {
            ScheduleMode::Compute => {
                // vars: x.. , t
                let t = nx;
                let mut lp = LpProblem::new(nx + 1);
                lp.set_objective(t, 1.0);
                for g in 0..g_count {
                    let mut terms: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (v, 1.0)).collect();
                    terms.push((t, -1.0));
                    let row = lp.add(terms, Relation::Le, 0.0);
                    me.gpu_rows.push((row, g));
                }
                for e in 0..e_count {
                    let terms = me.var_of[e].iter().map(|&v| (v, 1.0)).collect();
                    let row = lp.add(terms, Relation::Eq, 0.0);
                    me.eq_row.push(row);
                }
                lp
            }
            ScheduleMode::CommAware { alpha } => {
                // vars: x [0,nx), l [nx,2nx), comp, comm
                let comp = 2 * nx;
                let comm = 2 * nx + 1;
                let mut lp = LpProblem::new(2 * nx + 2);
                lp.set_objective(comp, 1.0);
                lp.set_objective(comm, *alpha);
                // comp >= gpu compute
                for g in 0..g_count {
                    let mut terms: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (v, 1.0)).collect();
                    terms.push((comp, -1.0));
                    lp.add(terms, Relation::Le, 0.0);
                }
                // l <= x (row) ; l <= input (implicit variable bound,
                // updated per micro-batch — never enters the row count)
                for e in 0..e_count {
                    for (r, &g) in p.replicas[e].iter().enumerate() {
                        let xv = me.var_of[e][r];
                        let lv = nx + xv;
                        lp.add(vec![(lv, 1.0), (xv, -1.0)], Relation::Le, 0.0);
                        lp.set_upper(lv, 0.0);
                        me.input_cap_vars.push((lv, e, g));
                    }
                }
                // send: total_input_g - Σ l_g <= comm  ->  -Σl - comm <= -total_g
                // recv: Σ x_g - Σ l_g - comm <= 0
                for g in 0..g_count {
                    let mut send_terms: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (nx + v, -1.0)).collect();
                    send_terms.push((comm, -1.0));
                    let row = lp.add(send_terms, Relation::Le, 0.0);
                    me.send_rows.push((row, g));

                    let mut recv_terms: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (v, 1.0)).collect();
                    recv_terms.extend(on_gpu[g].iter().map(|&v| (nx + v, -1.0)));
                    recv_terms.push((comm, -1.0));
                    lp.add(recv_terms, Relation::Le, 0.0);
                }
                for e in 0..e_count {
                    let terms = me.var_of[e].iter().map(|&v| (v, 1.0)).collect();
                    let row = lp.add(terms, Relation::Eq, 0.0);
                    me.eq_row.push(row);
                }
                lp
            }
            ScheduleMode::TopoAware { alpha1, alpha2 } => {
                let topo = topo.expect("TopoAware needs topology");
                let nodes = g_count.div_ceil(topo.gpus_per_node);
                // vars: x [0,nx), l [nx,2nx), n [2nx,3nx), comp, ci, cj
                let comp = 3 * nx;
                let ci = 3 * nx + 1;
                let cj = 3 * nx + 2;
                let mut lp = LpProblem::new(3 * nx + 3);
                lp.set_objective(comp, 1.0);
                lp.set_objective(ci, *alpha1);
                lp.set_objective(cj, *alpha2);
                for g in 0..g_count {
                    let mut terms: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (v, 1.0)).collect();
                    terms.push((comp, -1.0));
                    lp.add(terms, Relation::Le, 0.0);
                }
                for e in 0..e_count {
                    for (r, &g) in p.replicas[e].iter().enumerate() {
                        let xv = me.var_of[e][r];
                        let lv = nx + xv;
                        let nv = 2 * nx + xv;
                        lp.add(vec![(lv, 1.0), (xv, -1.0)], Relation::Le, 0.0);
                        lp.add(vec![(lv, 1.0), (nv, -1.0)], Relation::Le, 0.0);
                        lp.add(vec![(nv, 1.0), (xv, -1.0)], Relation::Le, 0.0);
                        // per-replica and node-aggregated input caps as
                        // implicit variable bounds (~2·nx rows saved)
                        lp.set_upper(lv, 0.0);
                        me.input_cap_vars.push((lv, e, g));
                        lp.set_upper(nv, 0.0);
                        me.node_cap_vars.push((nv, e, topo.node_of(g)));
                    }
                }
                for g in 0..g_count {
                    // intra recv: Σ(n-l) - ci <= 0
                    let mut t1: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (2 * nx + v, 1.0)).collect();
                    t1.extend(on_gpu[g].iter().map(|&v| (nx + v, -1.0)));
                    t1.push((ci, -1.0));
                    lp.add(t1, Relation::Le, 0.0);
                    // inter recv: Σ(x-n) - cj <= 0
                    let mut t2: Vec<(usize, f64)> =
                        on_gpu[g].iter().map(|&v| (v, 1.0)).collect();
                    t2.extend(on_gpu[g].iter().map(|&v| (2 * nx + v, -1.0)));
                    t2.push((cj, -1.0));
                    lp.add(t2, Relation::Le, 0.0);
                }
                // inter send per node, normalized per GPU:
                // (node_total - Σ_{replicas on node} n) / gpn <= cj
                let gpn = topo.gpus_per_node as f64;
                for node in 0..nodes {
                    let mut terms: Vec<(usize, f64)> = Vec::new();
                    for g in 0..g_count {
                        if topo.node_of(g) == node {
                            terms.extend(on_gpu[g].iter().map(|&v| (2 * nx + v, -1.0)));
                        }
                    }
                    terms.push((cj, -gpn));
                    let row = lp.add(terms, Relation::Le, 0.0);
                    me.node_send_rows.push((row, node));
                }
                for e in 0..e_count {
                    let terms = me.var_of[e].iter().map(|&v| (v, 1.0)).collect();
                    let row = lp.add(terms, Relation::Eq, 0.0);
                    me.eq_row.push(row);
                }
                lp
            }
            ScheduleMode::Decomposed { .. } => {
                // the real constraint matrices live per block inside
                // `decompose::DecomposedState`; the monolithic solver gets
                // a trivially satisfiable placeholder and is never invoked
                let mut lp = LpProblem::new(1);
                lp.set_objective(0, 1.0);
                lp.add(vec![(0, 1.0)], Relation::Le, 1.0);
                lp
            }
        };
        me.problem = Some(problem);
        me
    }

    fn build(&mut self) -> LpProblem {
        self.problem.take().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::placement::graph::max_induced_density_exact;
    use crate::rng::{Rng, Zipf};

    fn ring4() -> Placement {
        Placement::from_replicas(4, vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]])
    }

    fn uniform_inputs(loads: &[u64], num_gpus: usize) -> LoadMatrix {
        // distribute each expert's load evenly over source GPUs
        let mut m = LoadMatrix::zeros(loads.len(), num_gpus);
        for (e, &l) in loads.iter().enumerate() {
            for g in 0..num_gpus {
                let share = l / num_gpus as u64
                    + if (g as u64) < l % num_gpus as u64 { 1 } else { 0 };
                m.set(e, g, share);
            }
        }
        m
    }

    #[test]
    fn figure3c_achieves_perfect_balance() {
        // paper's worked example: loads 4,6,6,8 on the ring -> all GPUs at 6
        let p = ring4();
        let loads = uniform_inputs(&[4, 6, 6, 8], 4);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&loads);
        assert_eq!(sched.gpu_loads(&p), vec![6, 6, 6, 6]);
        assert!((sched.stats.lp_objective - 6.0).abs() < 1e-6);
    }

    #[test]
    fn lp_objective_equals_eq3_density() {
        // Eq. 3 identity: LP optimum == max induced subgraph density
        let mut rng = Rng::new(17);
        for trial in 0..25 {
            let p = cayley_graph_placement(8, 16);
            let zipf = Zipf::new(16, 0.8);
            let mut loads = vec![0u64; 16];
            for _ in 0..2000 {
                loads[zipf.sample(&mut rng)] += 1;
            }
            let lm = uniform_inputs(&loads, 8);
            let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
            let sched = s.schedule(&lm);
            let loads_f: Vec<f64> = loads.iter().map(|&l| l as f64).collect();
            let density = max_induced_density_exact(&p, &loads_f).density;
            assert!(
                (sched.stats.lp_objective - density).abs() < 1e-5,
                "trial {trial}: LP {} != density {}",
                sched.stats.lp_objective,
                density
            );
        }
    }

    #[test]
    fn replica_loads_conserve_expert_totals() {
        let p = ring4();
        let lm = uniform_inputs(&[13, 7, 22, 5], 4);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        for e in 0..4 {
            let sum: u64 = sched.replica_loads[e].iter().sum();
            assert_eq!(sum, lm.expert_load(e), "expert {e}");
        }
    }

    #[test]
    fn warm_start_matches_cold_across_batches() {
        let p = cayley_graph_placement(8, 16);
        let mut warm_s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let mut cold_s = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions { warm_start: false, ..Default::default() },
        );
        let mut rng = Rng::new(5);
        for batch in 0..20 {
            let mut lm = LoadMatrix::zeros(16, 8);
            for _ in 0..1000 {
                let e = rng.below(16) as usize;
                let g = rng.below(8) as usize;
                lm.add(e, g, 1);
            }
            let a = warm_s.schedule(&lm);
            let b = cold_s.schedule(&lm);
            assert!(
                (a.stats.lp_objective - b.stats.lp_objective).abs() < 1e-5,
                "batch {batch}: warm {} cold {}",
                a.stats.lp_objective,
                b.stats.lp_objective
            );
            if batch > 0 {
                assert!(a.stats.warm, "warm path not taken at batch {batch}");
            }
        }
    }

    #[test]
    fn warm_start_uses_fewer_pivots_on_similar_loads() {
        let p = cayley_graph_placement(8, 32);
        let mut s = MicroEpScheduler::new(p, None, SchedulerOptions::default());
        let mut rng = Rng::new(9);
        let mut lm = LoadMatrix::zeros(32, 8);
        for _ in 0..4000 {
            lm.add(rng.below(32) as usize, rng.below(8) as usize, 1);
        }
        let first = s.schedule(&lm);
        // small perturbation
        lm.add(3, 2, 5);
        lm.add(7, 1, 3);
        let second = s.schedule(&lm);
        assert!(second.stats.warm);
        assert!(
            second.stats.lp_iterations <= first.stats.lp_iterations / 2 + 2,
            "warm {} vs cold {}",
            second.stats.lp_iterations,
            first.stats.lp_iterations
        );
    }

    #[test]
    fn comm_aware_reduces_traffic() {
        // CommAware with large alpha should keep more tokens local than
        // pure Compute mode, at equal-or-worse compute balance.
        let p = ring4();
        // tokens already sit on GPUs hosting their experts
        let mut lm = LoadMatrix::zeros(4, 4);
        for e in 0..4 {
            let home = p.replicas[e][0];
            lm.set(e, home, 40);
        }
        let mut s_comp = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let mut s_comm = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions {
                mode: ScheduleMode::CommAware { alpha: 5.0 },
                ..Default::default()
            },
        );
        let a = s_comp.schedule(&lm);
        let b = s_comm.schedule(&lm);
        let vol = |s: &Schedule| s.comm_volumes(4).0.iter().sum::<u64>();
        assert!(
            vol(&b) <= vol(&a),
            "comm-aware traffic {} > compute-only {}",
            vol(&b),
            vol(&a)
        );
    }

    #[test]
    fn comm_aware_still_balances_when_alpha_small() {
        let p = ring4();
        let lm = uniform_inputs(&[4, 6, 6, 8], 4);
        let mut s = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions {
                mode: ScheduleMode::CommAware { alpha: 0.01 },
                ..Default::default()
            },
        );
        let sched = s.schedule(&lm);
        let max = *sched.gpu_loads(&p).iter().max().unwrap();
        assert!(max <= 7, "loads {:?}", sched.gpu_loads(&p));
    }

    #[test]
    fn topo_aware_solves_and_balances() {
        let topo = Topology::new(8, 4, 2, 4); // 2 nodes of 4 GPUs
        let p = cayley_graph_placement(8, 16);
        let mut s = MicroEpScheduler::new(
            p.clone(),
            Some(topo),
            SchedulerOptions {
                mode: ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.0 },
                topo_aware_routing: true,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(3);
        let mut lm = LoadMatrix::zeros(16, 8);
        for _ in 0..1600 {
            lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
        }
        let sched = s.schedule(&lm);
        for e in 0..16 {
            assert_eq!(
                sched.replica_loads[e].iter().sum::<u64>(),
                lm.expert_load(e)
            );
        }
        let imb = sched.imbalance(&p);
        assert!(imb < 1.2, "topo-aware imbalance {imb}");
    }

    #[test]
    fn empty_batch_is_fine() {
        let p = ring4();
        let lm = LoadMatrix::zeros(4, 4);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        assert_eq!(sched.gpu_loads(&p), vec![0, 0, 0, 0]);
        assert!(sched.routes.is_empty());
    }

    #[test]
    fn lp_rungs_are_recorded() {
        let p = ring4();
        let lm = uniform_inputs(&[4, 6, 6, 8], 4);
        let mut s = MicroEpScheduler::new(p, None, SchedulerOptions::default());
        let first = s.schedule(&lm);
        assert_eq!(first.stats.rung, crate::stats::DegradationRung::ColdLp);
        assert_eq!(first.stats.budget_exhausted, None);
        assert_eq!(first.stats.fallback_excess, 0.0);
        let second = s.schedule(&lm);
        assert_eq!(second.stats.rung, crate::stats::DegradationRung::WarmLp);
    }

    #[test]
    fn budget_starved_scheduler_degrades_to_greedy() {
        let p = ring4();
        let lm = uniform_inputs(&[4, 6, 6, 8], 4);
        let mut s = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions {
                budget: crate::lp::SolveBudget::with_max_pivots(0),
                ..Default::default()
            },
        );
        let sched = s.schedule(&lm);
        assert_eq!(sched.stats.rung, crate::stats::DegradationRung::Greedy);
        assert_eq!(sched.stats.budget_exhausted, Some(crate::lp::BudgetReason::Pivots));
        assert!(sched.stats.lp_objective.is_nan(), "no LP rung produced this plan");
        assert!(sched.stats.fallback_excess >= 0.0);
        // the plan is still feasible: every expert's total conserved, and
        // the greedy bound T / R_min = 24 / 2 holds
        for e in 0..4 {
            assert_eq!(sched.replica_loads[e].iter().sum::<u64>(), lm.expert_load(e));
        }
        assert!(sched.stats.max_gpu_load <= 12);
    }

    #[test]
    fn injected_faults_degrade_without_breaking_feasibility() {
        use crate::faults::{Fault, FaultPlan};
        use crate::stats::DegradationRung;
        let p = ring4();
        let plan = FaultPlan::with_faults(vec![
            (1, 0, Fault::NanLoads),
            (2, 0, Fault::ForceInfeasible),
            (3, 0, Fault::BudgetStarvation),
            (4, 0, Fault::OverflowLoads),
        ]);
        let mut s = MicroEpScheduler::new(
            p,
            None,
            SchedulerOptions {
                faults: Some(std::sync::Arc::new(plan)),
                ..Default::default()
            },
        );
        let lm = uniform_inputs(&[13, 7, 22, 5], 4);
        for step in 0..6 {
            let sched = s.schedule(&lm);
            for e in 0..4 {
                assert_eq!(
                    sched.replica_loads[e].iter().sum::<u64>(),
                    lm.expert_load(e),
                    "step {step} expert {e}"
                );
            }
            let expect_greedy = (1..=4).contains(&step);
            assert_eq!(
                sched.stats.rung == DegradationRung::Greedy,
                expect_greedy,
                "step {step}: rung {:?}",
                sched.stats.rung
            );
            if step == 3 {
                assert_eq!(
                    sched.stats.budget_exhausted,
                    Some(crate::lp::BudgetReason::Pivots),
                    "starvation step must report the pivot cap"
                );
            }
        }
    }

    #[test]
    fn speculate_does_not_consume_fault_slots() {
        use crate::faults::{Fault, FaultPlan};
        use crate::stats::DegradationRung;
        let plan = FaultPlan::with_faults(vec![(1, 0, Fault::NanLoads)]);
        let mut s = MicroEpScheduler::new(
            ring4(),
            None,
            SchedulerOptions {
                faults: Some(std::sync::Arc::new(plan)),
                ..Default::default()
            },
        );
        let lm = uniform_inputs(&[4, 6, 6, 8], 4);
        let a = s.schedule(&lm); // commit step 0
        assert_ne!(a.stats.rung, DegradationRung::Greedy);
        let sp = s.speculate(&lm); // not a commit: step stays at 1
        assert_ne!(sp.stats.rung, DegradationRung::Greedy);
        let b = s.schedule(&lm); // commit step 1 — the injected NaN fires here
        assert_eq!(b.stats.rung, DegradationRung::Greedy);
    }
}
