//! Algorithm 1: routing tokens to expert replicas (§5.2, App. A.1).
//!
//! Token ranges (never individual tokens) are matched against replica
//! budgets `x_e^g` in up to three passes:
//!
//! 1. **local** (locality-aware, §5.2): tokens on GPU g → g's own replica;
//! 2. **node** (topology-aware, App. A.1): remaining tokens → replicas on
//!    the same node;
//! 3. **global**: sequential sweep over sources × replicas.
//!
//! The sweep order is deterministic, so every device in the MicroEP group
//! computes the identical route set from the all-gathered `input_e^g`
//! (§5.3 consistency).

use super::{LoadMatrix, Route};
use crate::placement::Placement;
use crate::topology::Topology;

/// Route all tokens given integer replica budgets. Returns ranges covering
/// every input token exactly once (including src == dst "stay local" ranges,
/// which cost no communication).
pub fn route_tokens(
    placement: &Placement,
    input: &LoadMatrix,
    replica_loads: &[Vec<u64>],
    locality_aware: bool,
    topo: Option<&Topology>,
) -> Vec<Route> {
    let e_count = placement.num_experts;
    let g_count = placement.num_gpus;
    let mut routes = Vec::new();

    // remaining input per (e, g) and remaining budget per (e, replica idx)
    let mut rem_in: Vec<Vec<u64>> = (0..e_count)
        .map(|e| (0..g_count).map(|g| input.get(e, g)).collect())
        .collect();
    let mut rem_x: Vec<Vec<u64>> = replica_loads.to_vec();

    for e in 0..e_count {
        let grp = &placement.replicas[e];

        // pass 1: local tokens to local replicas (Alg. 1 lines 4-9)
        if locality_aware {
            for (r, &g) in grp.iter().enumerate() {
                let y = rem_in[e][g].min(rem_x[e][r]);
                if y > 0 {
                    routes.push(Route { expert: e, src: g, dst: g, tokens: y });
                    rem_in[e][g] -= y;
                    rem_x[e][r] -= y;
                }
            }
        }

        // pass 2: same-node replicas (App. A.1 topology-aware routing)
        if let Some(topo) = topo {
            for g in 0..g_count {
                if rem_in[e][g] == 0 {
                    continue;
                }
                for (r, &g2) in grp.iter().enumerate() {
                    if g2 == g || !topo.same_node(g, g2) {
                        continue;
                    }
                    let y = rem_in[e][g].min(rem_x[e][r]);
                    if y > 0 {
                        routes.push(Route { expert: e, src: g, dst: g2, tokens: y });
                        rem_in[e][g] -= y;
                        rem_x[e][r] -= y;
                    }
                    if rem_in[e][g] == 0 {
                        break;
                    }
                }
            }
        }

        // pass 3: global sequential sweep (Alg. 1 lines 10-16)
        let mut r = 0usize;
        for g in 0..g_count {
            while rem_in[e][g] > 0 {
                while r < grp.len() && rem_x[e][r] == 0 {
                    r += 1;
                }
                assert!(
                    r < grp.len(),
                    "routing ran out of replica budget for expert {e} \
                     (Σx < load_e — rounding bug?)"
                );
                let y = rem_in[e][g].min(rem_x[e][r]);
                routes.push(Route { expert: e, src: g, dst: grp[r], tokens: y });
                rem_in[e][g] -= y;
                rem_x[e][r] -= y;
            }
        }
        debug_assert!(rem_x[e].iter().all(|&v| v == 0), "unused budget for expert {e}");
    }
    routes
}

/// Verify a route set against inputs and budgets (test/diagnostic helper).
pub fn check_routes(
    placement: &Placement,
    input: &LoadMatrix,
    replica_loads: &[Vec<u64>],
    routes: &[Route],
) -> Result<(), String> {
    let e_count = placement.num_experts;
    let g_count = placement.num_gpus;
    let mut from = vec![vec![0u64; g_count]; e_count];
    let mut to = vec![std::collections::HashMap::<usize, u64>::new(); e_count];
    for r in routes {
        from[r.expert][r.src] += r.tokens;
        *to[r.expert].entry(r.dst).or_default() += r.tokens;
        if !placement.hosts(r.dst, r.expert) {
            return Err(format!("route to non-resident replica: {r:?}"));
        }
    }
    for e in 0..e_count {
        for g in 0..g_count {
            if from[e][g] != input.get(e, g) {
                return Err(format!(
                    "expert {e} gpu {g}: routed {} != input {}",
                    from[e][g],
                    input.get(e, g)
                ));
            }
        }
        for (r, &g) in placement.replicas[e].iter().enumerate() {
            let got = to[e].get(&g).copied().unwrap_or(0);
            if got != replica_loads[e][r] {
                return Err(format!(
                    "expert {e} replica on gpu {g}: received {got} != budget {}",
                    replica_loads[e][r]
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::scheduler::rounding::round_preserving_sum;

    fn ring4() -> Placement {
        Placement::from_replicas(4, vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]])
    }

    fn random_case(seed: u64) -> (Placement, LoadMatrix, Vec<Vec<u64>>) {
        let mut rng = Rng::new(seed);
        let p = crate::placement::random::random_placement(6, 12, 2, &mut rng);
        let mut lm = LoadMatrix::zeros(12, 6);
        for _ in 0..800 {
            lm.add(rng.below(12) as usize, rng.below(6) as usize, 1);
        }
        // random fractional budgets, then round
        let budgets: Vec<Vec<u64>> = (0..12)
            .map(|e| {
                let total = lm.expert_load(e);
                let k = p.replica_count(e);
                let fr: Vec<f64> = (0..k).map(|_| rng.f64()).collect();
                let s: f64 = fr.iter().sum();
                let fr: Vec<f64> = fr.iter().map(|v| v / s * total as f64).collect();
                round_preserving_sum(&fr, total)
            })
            .collect();
        (p, lm, budgets)
    }

    #[test]
    fn conservation_random_cases() {
        for seed in 0..25 {
            let (p, lm, budgets) = random_case(seed);
            let routes = route_tokens(&p, &lm, &budgets, true, None);
            check_routes(&p, &lm, &budgets, &routes).unwrap();
        }
    }

    #[test]
    fn conservation_without_locality() {
        for seed in 0..10 {
            let (p, lm, budgets) = random_case(seed + 100);
            let routes = route_tokens(&p, &lm, &budgets, false, None);
            check_routes(&p, &lm, &budgets, &routes).unwrap();
        }
    }

    #[test]
    fn locality_reduces_traffic() {
        for seed in 0..10 {
            let (p, lm, budgets) = random_case(seed + 200);
            let with = route_tokens(&p, &lm, &budgets, true, None);
            let without = route_tokens(&p, &lm, &budgets, false, None);
            let vol = |rs: &[Route]| -> u64 {
                rs.iter().filter(|r| r.src != r.dst).map(|r| r.tokens).sum()
            };
            assert!(
                vol(&with) <= vol(&without),
                "seed {seed}: locality increased traffic"
            );
        }
    }

    #[test]
    fn local_tokens_stay_local_when_budget_allows() {
        let p = ring4();
        let mut lm = LoadMatrix::zeros(4, 4);
        lm.set(0, 0, 10); // expert 0 replicas on {0,3}
        let budgets = vec![vec![10, 0], vec![0, 0], vec![0, 0], vec![0, 0]];
        let routes = route_tokens(&p, &lm, &budgets, true, None);
        assert_eq!(routes, vec![Route { expert: 0, src: 0, dst: 0, tokens: 10 }]);
    }

    #[test]
    fn topo_pass_prefers_same_node() {
        // 4 GPUs, 2 nodes of 2; expert 0 replicas on {1, 2}; tokens on 0.
        // node(0)={0,1}: topo pass should send to GPU 1 first.
        let p = Placement::from_replicas(4, vec![vec![1, 2], vec![0, 3], vec![0, 1], vec![2, 3]]);
        let topo = Topology::new(4, 2, 2, 2);
        let mut lm = LoadMatrix::zeros(4, 4);
        lm.set(0, 0, 8);
        let budgets = vec![vec![5, 3], vec![0, 0], vec![0, 0], vec![0, 0]];
        let routes = route_tokens(&p, &lm, &budgets, true, Some(&topo));
        // first 5 tokens go to same-node GPU 1; remaining 3 cross nodes
        assert!(routes.contains(&Route { expert: 0, src: 0, dst: 1, tokens: 5 }));
        assert!(routes.contains(&Route { expert: 0, src: 0, dst: 2, tokens: 3 }));
    }

    #[test]
    fn deterministic_output() {
        let (p, lm, budgets) = random_case(7);
        let a = route_tokens(&p, &lm, &budgets, true, None);
        let b = route_tokens(&p, &lm, &budgets, true, None);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "ran out of replica budget")]
    fn underfunded_budget_panics() {
        let p = ring4();
        let mut lm = LoadMatrix::zeros(4, 4);
        lm.set(0, 1, 5);
        let budgets = vec![vec![2, 2], vec![0, 0], vec![0, 0], vec![0, 0]]; // 4 < 5
        route_tokens(&p, &lm, &budgets, true, None);
    }
}
