//! Two-level Dantzig–Wolfe-style decomposition of the scheduling LP
//! ([`super::ScheduleMode::Decomposed`]) — hierarchical scheduling past
//! ~10³ GPUs.
//!
//! The monolithic LPP solve is `O(G)` rows × `O(nx)` columns; past a few
//! hundred GPUs even a warm solve blows the ~1 ms per-micro-batch budget.
//! But the constraint matrix is *block-angular*: per-GPU load rows only
//! couple replicas on that GPU, and only the per-expert conservation rows
//! span blocks. This module exploits that exactly the way Dantzig–Wolfe
//! decomposition does — a small coordination master over block aggregates,
//! plus one independent subproblem per block:
//!
//! * **Blocks** are `nodes_per_block` consecutive topology nodes; the block
//!   of GPU `g` is `topo.node_of(g) / nodes_per_block`. Blocks partition
//!   the GPUs, so the global max load is the max over block maxima.
//! * **Master**: a deterministic weighted water-fill splits each expert's
//!   load over the blocks hosting its replicas, proportional to effective
//!   block capacities `κ_b` (initialized to the block's used-GPU count).
//!   Experts are placed in descending-load order, each leveling its
//!   candidate blocks' normalized fill `assigned_b / κ_b` — the same LPT
//!   water-fill the greedy fallback uses, lifted to block granularity.
//! * **Subproblem** per block: `min t_b` s.t. per-GPU `Σx − t_b ≤ 0` and
//!   per block-expert `Σx = y_{e,b}`. The matrix is fixed at construction;
//!   each round only rewrites equality rhs — exactly the rhs-update shape
//!   [`WarmSolver`] warm-starts. Subproblems solve in parallel with scoped
//!   threads (each block owns its solver outright, like
//!   [`super::schedule_layers_parallel`]); per-layer decomposed schedulers
//!   additionally ride the [`crate::engine`] worker pool across layers.
//! * **Feedback / iteration**: after a round, `κ_b ← assigned_b / t_b`
//!   (capped at the block's GPU count) — blocks that balanced poorly
//!   (interior structure forced a high `t_b`) attract less load next
//!   round. The loop stops when the achieved max is within `tol` of the
//!   global fractional lower bound ([`fallback::lp_lower_bound`]), when it
//!   stalls, or after `max_outer_iters` rounds; the best iterate is kept.
//!
//! **Determinism** (§5.3 requirement): the master is pure, ordered IEEE
//! arithmetic; subproblem results depend only on each block's own solver
//! state and rhs, never on thread scheduling; the reduction over blocks is
//! index-ordered. Schedules are therefore bit-identical across devices and
//! worker counts — `distributed.rs` pins this.
//!
//! **Degradation** is block-scoped: a subproblem that exhausts its
//! [`crate::lp::SolveBudget`] (or stalls numerically) degrades to a
//! water-fill *within that block only*; the layer's rung drops to
//! [`DegradationRung::Greedy`] only when every block degraded.

use super::fallback;
use super::{LoadMatrix, SchedulerOptions};
use crate::lp::{
    BudgetReason, LpProblem, Relation, SimplexError, SolveBudget, SolveStats, WarmSolver,
};
use crate::placement::Placement;
use crate::stats::DegradationRung;
use crate::topology::Topology;

/// Per-solve meters for the decomposed path, carried on
/// [`super::ScheduleStats::decompose`] and rolled up into
/// [`crate::stats::DecomposeStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecomposeMeters {
    /// Master/subproblem coordination rounds actually run.
    pub outer_iters: u32,
    /// Simplex pivots summed over every block subproblem solve (all
    /// rounds).
    pub subproblem_pivots: u64,
    /// Final relative gap of the kept iterate to the global fractional
    /// lower bound: `(max_b t_b − LB) / LB` (0 when the bound is 0).
    pub master_gap: f64,
    /// Subproblem blocks in the partition (those hosting ≥1 replica).
    pub blocks: u32,
    /// Blocks of the kept iterate whose subproblem degraded to the
    /// block-local water-fill.
    pub blocks_degraded: u32,
}

/// One block's subproblem: the GPUs of `nodes_per_block` consecutive
/// nodes, the expert replicas living there, and a warm-started LP over
/// them.
struct BlockSub {
    /// Materialized (replica-hosting) GPUs in this block.
    num_gpus: usize,
    /// Block-expert descriptors, ascending global expert id.
    experts: Vec<BlockExpert>,
    /// Equality-row index per block-expert (rhs = this round's `y_{e,b}`).
    eq_row: Vec<usize>,
    /// LP variable per (block-expert, replica).
    var_of: Vec<Vec<usize>>,
    warm: WarmSolver,
    solved_once: bool,
}

/// An expert's footprint inside one block.
struct BlockExpert {
    /// Global expert id.
    e: usize,
    /// Replica indices into `placement.replicas[e]` hosted in this block.
    reps: Vec<usize>,
    /// Local GPU slot of each replica (parallel to `reps`).
    gpu_local: Vec<usize>,
}

/// Result of one block subproblem solve.
struct BlockOutcome {
    /// Fractional loads per (block-expert, replica).
    frac: Vec<Vec<f64>>,
    /// Max implied GPU load inside the block.
    t: f64,
    /// LP work counters (zero when the block degraded).
    lp: SolveStats,
    warm: bool,
    degraded: bool,
    budget: Option<BudgetReason>,
}

/// The iterate retained as the solve's answer (lowest `max_b t_b`).
struct Kept {
    t: f64,
    frac: Vec<Vec<Vec<f64>>>,
    degraded: Vec<bool>,
}

/// What [`DecomposedState::solve`] hands back to the scheduler.
pub(crate) struct DecomposedSolve {
    /// Global fractional replica loads, aligned with `placement.replicas`.
    pub(crate) frac: Vec<Vec<f64>>,
    pub(crate) meters: DecomposeMeters,
    pub(crate) rung: DegradationRung,
    pub(crate) budget_exhausted: Option<BudgetReason>,
    /// Fractional objective of the kept iterate (global max GPU load).
    pub(crate) objective: f64,
    /// Global fractional lower bound the gap was measured against.
    pub(crate) lower_bound: f64,
    /// LP work totals across all subproblem solves.
    pub(crate) lp: SolveStats,
}

/// The two-level solver state owned by a
/// [`super::MicroEpScheduler`] in decomposed mode.
pub(crate) struct DecomposedState {
    blocks: Vec<BlockSub>,
    /// Per expert: `(block index, block-expert index)` for every block
    /// hosting one of its replicas.
    expert_sites: Vec<Vec<(usize, usize)>>,
    max_outer_iters: usize,
    tol: f64,
}

impl DecomposedState {
    /// Partition the placement into node blocks and lower one subproblem
    /// LP per (non-empty) block. Like the monolithic builder, this fixes
    /// every constraint matrix once; solves only rewrite equality rhs.
    pub(crate) fn new(
        placement: &Placement,
        topo: &Topology,
        opts: &SchedulerOptions,
        nodes_per_block: usize,
        max_outer_iters: usize,
        tol: f64,
    ) -> Self {
        assert!(nodes_per_block >= 1, "nodes_per_block must be positive");
        assert!(max_outer_iters >= 1, "max_outer_iters must be positive");
        assert!(tol.is_finite() && tol >= 0.0, "tol must be finite and non-negative");
        let gpus_per_block = topo.gpus_per_node * nodes_per_block;
        let raw_blocks = placement.num_gpus.div_ceil(gpus_per_block);
        // (expert, replica, gpu) per raw block; ascending (e, r) by
        // construction of the scan
        let mut members: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); raw_blocks];
        for (e, reps) in placement.replicas.iter().enumerate() {
            for (r, &g) in reps.iter().enumerate() {
                members[topo.node_of(g) / nodes_per_block].push((e, r, g));
            }
        }
        let mut blocks: Vec<BlockSub> = Vec::new();
        let mut expert_sites: Vec<Vec<(usize, usize)>> =
            vec![Vec::new(); placement.num_experts];
        for mem in members.into_iter().filter(|m| !m.is_empty()) {
            let bi = blocks.len();
            let mut gpus: Vec<usize> = mem.iter().map(|&(_, _, g)| g).collect();
            gpus.sort_unstable();
            gpus.dedup();
            let mut experts: Vec<BlockExpert> = Vec::new();
            for &(e, r, g) in &mem {
                if experts.last().map(|x| x.e) != Some(e) {
                    expert_sites[e].push((bi, experts.len()));
                    experts.push(BlockExpert { e, reps: Vec::new(), gpu_local: Vec::new() });
                }
                let be = experts.last_mut().unwrap();
                be.reps.push(r);
                be.gpu_local.push(gpus.binary_search(&g).unwrap());
            }
            // vars: one x per block replica, then t; rows: per local GPU
            // `Σx − t ≤ 0`, then per block-expert `Σx = y` (rhs per round)
            let nx: usize = experts.iter().map(|x| x.reps.len()).sum();
            let t = nx;
            let mut lp = LpProblem::new(nx + 1);
            lp.set_objective(t, 1.0);
            let mut var_of: Vec<Vec<usize>> = Vec::with_capacity(experts.len());
            let mut next = 0usize;
            for x in &experts {
                var_of.push((0..x.reps.len()).map(|k| next + k).collect());
                next += x.reps.len();
            }
            let mut on_gpu: Vec<Vec<usize>> = vec![Vec::new(); gpus.len()];
            for (x, vars) in experts.iter().zip(&var_of) {
                for (k, &lg) in x.gpu_local.iter().enumerate() {
                    on_gpu[lg].push(vars[k]);
                }
            }
            for vars in &on_gpu {
                let mut terms: Vec<(usize, f64)> = vars.iter().map(|&v| (v, 1.0)).collect();
                terms.push((t, -1.0));
                lp.add(terms, Relation::Le, 0.0);
            }
            let mut eq_row = Vec::with_capacity(experts.len());
            for vars in &var_of {
                let terms = vars.iter().map(|&v| (v, 1.0)).collect();
                eq_row.push(lp.add(terms, Relation::Eq, 0.0));
            }
            let mut warm = WarmSolver::with_kind(lp, opts.solver);
            warm.set_budget(opts.budget);
            blocks.push(BlockSub {
                num_gpus: gpus.len(),
                experts,
                eq_row,
                var_of,
                warm,
                solved_once: false,
            });
        }
        DecomposedState { blocks, expert_sites, max_outer_iters, tol }
    }

    /// Re-budget every block solver (the chaos harness's starvation fault
    /// goes through here so exhaustion degrades blocks, not the layer).
    pub(crate) fn set_budget(&mut self, budget: SolveBudget) {
        for b in &mut self.blocks {
            b.warm.set_budget(budget);
        }
    }

    /// Run the two-level solve for one micro-batch. `use_warm` gates the
    /// *first* round's warm start (later rounds always repair from the
    /// previous round's basis — same state on every device, so still
    /// deterministic). `trace` records one
    /// [`crate::obs::Span::DecomposeRound`] per round per block (the
    /// scheduler passes the disabled tracer for non-committing solves);
    /// tracing observes, never steers — the iteration is identical either
    /// way.
    pub(crate) fn solve(
        &mut self,
        placement: &Placement,
        loads: &LoadMatrix,
        use_warm: bool,
        trace: &crate::obs::Tracer,
    ) -> DecomposedSolve {
        let expert_loads = loads.expert_loads();
        let lower_bound = fallback::lp_lower_bound(placement, loads);
        let nb = self.blocks.len();
        let mut kappa: Vec<f64> = self.blocks.iter().map(|b| b.num_gpus as f64).collect();
        let mut meters = DecomposeMeters { blocks: nb as u32, ..Default::default() };
        let mut lp_total = SolveStats::default();
        let mut budget_exhausted: Option<BudgetReason> = None;
        let mut first_round_all_warm = false;
        let mut best: Option<Kept> = None;
        let mut prev_t = f64::INFINITY;

        for outer in 0..self.max_outer_iters {
            let (y, assigned) = self.allocate(&expert_loads, &kappa);
            let warm_round = if outer == 0 { use_warm } else { true };
            let outcomes = solve_blocks(&mut self.blocks, &y, warm_round);
            meters.outer_iters += 1;
            let mut t_max = 0.0f64;
            let mut all_warm = true;
            for o in &outcomes {
                t_max = t_max.max(o.t);
                lp_total.pivots += o.lp.pivots;
                lp_total.dual_pivots += o.lp.dual_pivots;
                lp_total.bound_flips += o.lp.bound_flips;
                lp_total.refactorizations += o.lp.refactorizations;
                meters.subproblem_pivots += o.lp.pivots as u64;
                if budget_exhausted.is_none() {
                    budget_exhausted = o.budget;
                }
                if o.degraded || !o.warm {
                    all_warm = false;
                }
            }
            if outer == 0 {
                first_round_all_warm = all_warm;
            }
            let better = match &best {
                Some(k) => t_max < k.t,
                None => true,
            };
            if better {
                best = Some(Kept {
                    t: t_max,
                    frac: outcomes.iter().map(|o| o.frac.clone()).collect(),
                    degraded: outcomes.iter().map(|o| o.degraded).collect(),
                });
            }
            let gap = if lower_bound > 0.0 { (t_max - lower_bound) / lower_bound } else { 0.0 };
            // capacity feedback: blocks that balanced poorly shrink. Runs
            // before the convergence checks so the final round's κ is the
            // same value the per-round trace spans report (κ is only read
            // by the *next* round's allocate, so ordering is behaviorally
            // neutral).
            for (i, o) in outcomes.iter().enumerate() {
                let cap = self.blocks[i].num_gpus as f64;
                kappa[i] = if o.t > 1e-12 {
                    (assigned[i] / o.t).clamp(1e-9, cap)
                } else {
                    cap
                };
                trace.record(
                    0.0,
                    crate::obs::Span::DecomposeRound {
                        round: outer,
                        block: i,
                        gap,
                        kappa: kappa[i],
                    },
                );
            }
            if gap <= self.tol {
                break;
            }
            if (prev_t - t_max).abs() <= self.tol * t_max.max(1.0) {
                break; // stalled: more rounds would retrace this iterate
            }
            prev_t = t_max;
        }

        let kept = best.expect("max_outer_iters >= 1 ran at least one round");
        let degraded = kept.degraded.iter().filter(|&&d| d).count();
        meters.blocks_degraded = degraded as u32;
        meters.master_gap = if lower_bound > 0.0 {
            ((kept.t - lower_bound) / lower_bound).max(0.0)
        } else {
            0.0
        };
        let rung = if nb > 0 && degraded == nb {
            DegradationRung::Greedy
        } else if first_round_all_warm {
            DegradationRung::WarmLp
        } else {
            DegradationRung::ColdLp
        };
        let mut frac: Vec<Vec<f64>> =
            placement.replicas.iter().map(|g| vec![0.0; g.len()]).collect();
        for (b, bf) in self.blocks.iter().zip(&kept.frac) {
            for (be, x) in b.experts.iter().zip(bf) {
                for (k, &r) in be.reps.iter().enumerate() {
                    frac[be.e][r] = x[k];
                }
            }
        }
        DecomposedSolve {
            frac,
            meters,
            rung,
            budget_exhausted,
            objective: kept.t,
            lower_bound,
            lp: lp_total,
        }
    }

    /// Master step: deterministically water-fill each expert's load over
    /// the blocks hosting its replicas, weighted by capacities `kappa`.
    /// Returns per-block `y` (aligned with each block's experts) and the
    /// per-block assigned totals.
    fn allocate(&self, expert_loads: &[u64], kappa: &[f64]) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut y: Vec<Vec<f64>> =
            self.blocks.iter().map(|b| vec![0.0; b.experts.len()]).collect();
        let mut assigned = vec![0.0; self.blocks.len()];
        // descending load, ascending index — same order as the greedy
        let mut order: Vec<usize> = (0..expert_loads.len()).collect();
        order.sort_by_key(|&e| (std::cmp::Reverse(expert_loads[e]), e));
        for e in order {
            let load = expert_loads[e] as f64;
            if load == 0.0 || self.expert_sites[e].is_empty() {
                continue;
            }
            let sites = &self.expert_sites[e];
            if sites.len() == 1 {
                let (bi, be) = sites[0];
                y[bi][be] = load;
                assigned[bi] += load;
                continue;
            }
            // candidate blocks by normalized fill level, ties by index
            let mut lv: Vec<(f64, usize, usize)> = sites
                .iter()
                .map(|&(bi, be)| (assigned[bi] / kappa[bi], bi, be))
                .collect();
            lv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            // largest prefix the load can lift to (at least) the next
            // block's level, in weighted level space
            let mut fill = lv.len();
            let mut wsum = 0.0;
            let mut asum = 0.0;
            for (j, &(level, bi, _)) in lv.iter().enumerate() {
                if j > 0 && level * wsum - asum >= load {
                    fill = j;
                    break;
                }
                wsum += kappa[bi];
                asum += assigned[bi];
            }
            let lambda = (load + asum) / wsum;
            let mut acc = 0.0;
            for &(_, bi, be) in &lv[..fill] {
                let give = (kappa[bi] * lambda - assigned[bi]).max(0.0);
                y[bi][be] = give;
                assigned[bi] += give;
                acc += give;
            }
            // float residue → lowest block, clamped at zero with the
            // running totals kept in sync (same rule as the fallback)
            let residue = load - acc;
            if residue != 0.0 {
                let (_, bi, be) = lv[0];
                let old = y[bi][be];
                let new = (old + residue).max(0.0);
                y[bi][be] = new;
                assigned[bi] += new - old;
            }
        }
        (y, assigned)
    }
}

impl BlockSub {
    /// Solve this block's subproblem for the round's `y` (one entry per
    /// block-expert). Never fails: LP exhaustion degrades to the
    /// block-local water-fill.
    fn solve(&mut self, y: &[f64], warm_allowed: bool) -> BlockOutcome {
        let updates: Vec<(usize, f64)> =
            self.eq_row.iter().copied().zip(y.iter().copied()).collect();
        let use_warm = warm_allowed && self.solved_once;
        let result = self.warm.solve_with(&updates, use_warm);
        // a budget-exhausted warm attempt that fell through to cold still
        // counts as a budget event (mirrors the monolithic ladder)
        let mut budget = match (&result, &self.warm.last_warm_failure) {
            (Ok(_), Some(SimplexError::BudgetExhausted(r))) => Some(*r),
            _ => None,
        };
        match result {
            Ok(sol) => {
                self.solved_once = true;
                let frac: Vec<Vec<f64>> = self
                    .var_of
                    .iter()
                    .map(|vars| vars.iter().map(|&v| sol.x[v]).collect())
                    .collect();
                let t = self.implied_max(&frac);
                BlockOutcome {
                    frac,
                    t,
                    lp: self.warm.last_stats,
                    warm: self.warm.last_was_warm,
                    degraded: false,
                    budget,
                }
            }
            Err(e) => {
                if let SimplexError::BudgetExhausted(r) = &e {
                    budget = Some(*r);
                }
                let frac = self.greedy_fill(y);
                let t = self.implied_max(&frac);
                BlockOutcome {
                    frac,
                    t,
                    lp: SolveStats::default(),
                    warm: false,
                    degraded: true,
                    budget,
                }
            }
        }
    }

    /// Max per-GPU load inside the block implied by a fractional
    /// assignment (computed from the assignment, not the LP objective, so
    /// it is also valid for degraded blocks).
    fn implied_max(&self, frac: &[Vec<f64>]) -> f64 {
        let mut level = vec![0.0f64; self.num_gpus];
        for (be, x) in self.experts.iter().zip(frac) {
            for (k, &lg) in be.gpu_local.iter().enumerate() {
                level[lg] += x[k];
            }
        }
        level.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Block-local water-fill (the block's degradation rung): the same
    /// deterministic LPT fill as [`fallback::greedy_fraction`], restricted
    /// to this block's GPUs and this round's `y`.
    fn greedy_fill(&self, y: &[f64]) -> Vec<Vec<f64>> {
        let mut level = vec![0.0f64; self.num_gpus];
        let mut frac: Vec<Vec<f64>> =
            self.experts.iter().map(|x| vec![0.0; x.reps.len()]).collect();
        let mut order: Vec<usize> = (0..self.experts.len()).collect();
        order.sort_by(|&a, &b| {
            y[b].partial_cmp(&y[a]).unwrap().then(self.experts[a].e.cmp(&self.experts[b].e))
        });
        for bi in order {
            let load = y[bi];
            if load <= 0.0 {
                continue;
            }
            let slots = &self.experts[bi].gpu_local;
            let mut by_load: Vec<usize> = (0..slots.len()).collect();
            by_load.sort_by(|&a, &b| {
                level[slots[a]].partial_cmp(&level[slots[b]]).unwrap().then(a.cmp(&b))
            });
            let levels: Vec<f64> = by_load.iter().map(|&k| level[slots[k]]).collect();
            let mut fill = levels.len();
            let mut prefix_sum = 0.0;
            for (j, &lvl) in levels.iter().enumerate() {
                if j > 0 && j as f64 * lvl - prefix_sum >= load {
                    fill = j;
                    break;
                }
                prefix_sum += lvl;
            }
            let prefix: f64 = levels[..fill].iter().sum();
            let common = (load + prefix) / fill as f64;
            for (j, &k) in by_load[..fill].iter().enumerate() {
                let share = (common - levels[j]).max(0.0);
                frac[bi][k] = share;
                level[slots[k]] += share;
            }
            // any float residue is re-conserved by global integer rounding
        }
        frac
    }
}

/// Solve every block's subproblem, in parallel when it pays. Each block
/// owns its warm state outright, so results are bit-identical to the
/// serial loop regardless of thread count (the same argument as
/// [`super::schedule_layers_parallel`]).
fn solve_blocks(blocks: &mut [BlockSub], y: &[Vec<f64>], warm_allowed: bool) -> Vec<BlockOutcome> {
    let n = blocks.len();
    let workers = std::thread::available_parallelism().map_or(1, |p| p.get()).min(n);
    if workers <= 1 {
        return blocks.iter_mut().zip(y).map(|(b, yb)| b.solve(yb, warm_allowed)).collect();
    }
    let mut out: Vec<Option<BlockOutcome>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for ((b_chunk, y_chunk), o_chunk) in
            blocks.chunks_mut(chunk).zip(y.chunks(chunk)).zip(out.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for ((b, yb), slot) in b_chunk.iter_mut().zip(y_chunk).zip(o_chunk.iter_mut()) {
                    *slot = Some(b.solve(yb, warm_allowed));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("block solver thread completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::scheduler::{MicroEpScheduler, ScheduleMode};
    use crate::stats::DegradationRung;

    /// Each expert gets two adjacent-GPU pairs half a ring apart: replica
    /// freedom inside a block (the pair) times master freedom across
    /// blocks (the two pairs land in different blocks).
    fn paired_placement(gpus: usize, experts: usize) -> Placement {
        let half = gpus / 2;
        let reps = (0..experts)
            .map(|e| {
                let a = (2 * e) % half;
                let mut v = vec![a, a + 1, a + half, a + half + 1];
                v.sort_unstable();
                v.dedup();
                v
            })
            .collect();
        Placement::from_replicas(gpus, reps)
    }

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    fn dec_opts(nodes_per_block: usize) -> SchedulerOptions {
        SchedulerOptions {
            mode: ScheduleMode::Decomposed { nodes_per_block, max_outer_iters: 6, tol: 1e-3 },
            ..Default::default()
        }
    }

    fn topo16() -> Topology {
        Topology::new(16, 8, 2, 4) // one 16-GPU MicroEP group, 4 nodes of 4
    }

    #[test]
    fn decomposed_matches_exact_within_one_percent() {
        let p = paired_placement(16, 12);
        let mut exact = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let mut dec = MicroEpScheduler::new(p.clone(), Some(topo16()), dec_opts(1));
        for batch in 0..5 {
            let lm = random_lm(90 + batch, 12, 16, 4000);
            let a = exact.schedule(&lm);
            let b = dec.schedule(&lm);
            for e in 0..12 {
                assert_eq!(
                    b.replica_loads[e].iter().sum::<u64>(),
                    lm.expert_load(e),
                    "batch {batch} expert {e}: conservation"
                );
            }
            let m = b.stats.decompose.expect("decomposed meters recorded");
            assert!(m.blocks > 1, "partition must be nontrivial, got {} blocks", m.blocks);
            assert_eq!(m.blocks_degraded, 0, "batch {batch}");
            let (ea, eb) = (a.stats.max_gpu_load as f64, b.stats.max_gpu_load as f64);
            assert!(eb <= ea * 1.01 + 1.0, "batch {batch}: decomposed {eb} vs exact {ea}");
        }
    }

    #[test]
    fn warm_rung_engages_on_the_second_batch() {
        let p = paired_placement(16, 12);
        let mut dec = MicroEpScheduler::new(p, Some(topo16()), dec_opts(2));
        let lm = random_lm(11, 12, 16, 5000);
        let first = dec.schedule(&lm);
        assert_eq!(first.stats.rung, DegradationRung::ColdLp);
        assert!(first.stats.decompose.unwrap().outer_iters >= 1);
        let second = dec.schedule(&lm);
        assert_eq!(second.stats.rung, DegradationRung::WarmLp);
        assert!(second.stats.warm);
    }

    #[test]
    fn starved_budget_degrades_blocks_not_the_solve() {
        let p = paired_placement(16, 12);
        let mut dec = MicroEpScheduler::new(
            p,
            Some(topo16()),
            SchedulerOptions {
                budget: SolveBudget::with_max_pivots(0),
                ..dec_opts(1)
            },
        );
        let lm = random_lm(7, 12, 16, 3000);
        let sched = dec.schedule(&lm);
        for e in 0..12 {
            assert_eq!(sched.replica_loads[e].iter().sum::<u64>(), lm.expert_load(e));
        }
        assert_eq!(sched.stats.rung, DegradationRung::Greedy);
        assert_eq!(sched.stats.budget_exhausted, Some(BudgetReason::Pivots));
        let m = sched.stats.decompose.expect("meters survive degradation");
        assert_eq!(m.blocks_degraded, m.blocks, "every block degraded under a zero budget");
        assert!(sched.stats.fallback_excess >= 0.0);
    }

    #[test]
    fn empty_batch_is_fine() {
        let p = paired_placement(16, 12);
        let mut dec = MicroEpScheduler::new(p.clone(), Some(topo16()), dec_opts(1));
        let sched = dec.schedule(&LoadMatrix::zeros(12, 16));
        assert_eq!(sched.gpu_loads(&p), vec![0; 16]);
        assert!(sched.routes.is_empty());
        assert_eq!(sched.stats.decompose.unwrap().master_gap, 0.0);
    }
}
