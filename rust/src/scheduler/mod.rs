//! Token scheduling (§5): the short-term half of MicroEP.
//!
//! Per micro-batch, given `input_e^g` (tokens on GPU g routed to expert e by
//! the gate), the scheduler:
//!
//! 1. distributes each expert's load over its replicas by solving LPP 1
//!    (or the communication-aware LPP 4 / its topology-aware refinement),
//!    warm-starting from the previous micro-batch ([`lpp`]);
//! 2. rounds the fractional replica loads to integers without changing any
//!    expert's total ([`rounding`]);
//! 3. routes concrete token ranges to replicas with Algorithm 1, local
//!    tokens first ([`routing`]).
//!
//! [`distributed`] models §5.3's distributed deterministic execution: every
//! device runs the same algorithm on all-gathered inputs and must produce
//! bit-identical schedules.

pub mod decompose;
pub mod distributed;
pub mod fallback;
pub mod flow;
pub mod lpp;
pub mod rounding;
pub mod routing;

use crate::placement::Placement;

/// `input_e^g` — token counts per (expert, source GPU), expert-major.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadMatrix {
    /// Experts (rows).
    pub num_experts: usize,
    /// Source GPUs (columns).
    pub num_gpus: usize,
    data: Vec<u64>,
}

impl LoadMatrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(num_experts: usize, num_gpus: usize) -> Self {
        LoadMatrix { num_experts, num_gpus, data: vec![0; num_experts * num_gpus] }
    }

    /// Build from expert-major rows (all rows must share a length).
    pub fn from_rows(rows: Vec<Vec<u64>>) -> Self {
        let num_experts = rows.len();
        let num_gpus = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|r| r.len() == num_gpus));
        LoadMatrix { num_experts, num_gpus, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    /// `input_e^g`.
    pub fn get(&self, e: usize, g: usize) -> u64 {
        self.data[e * self.num_gpus + g]
    }

    #[inline]
    /// Overwrite `input_e^g`.
    pub fn set(&mut self, e: usize, g: usize, v: u64) {
        self.data[e * self.num_gpus + g] = v;
    }

    #[inline]
    /// Accumulate into `input_e^g`.
    pub fn add(&mut self, e: usize, g: usize, v: u64) {
        self.data[e * self.num_gpus + g] += v;
    }

    /// Total load of expert e across the group (`load_e`).
    pub fn expert_load(&self, e: usize) -> u64 {
        let base = e * self.num_gpus;
        self.data[base..base + self.num_gpus].iter().sum()
    }

    /// Total tokens originating on GPU g.
    pub fn gpu_input(&self, g: usize) -> u64 {
        (0..self.num_experts).map(|e| self.get(e, g)).sum()
    }

    /// Total tokens in the batch.
    pub fn total(&self) -> u64 {
        self.data.iter().sum()
    }

    /// All per-expert totals.
    pub fn expert_loads(&self) -> Vec<u64> {
        (0..self.num_experts).map(|e| self.expert_load(e)).collect()
    }
}

/// One routed token range: `tokens` tokens of `expert` moving from GPU
/// `src`'s queue to the replica on GPU `dst`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    /// Expert the tokens belong to.
    pub expert: usize,
    /// Source GPU (where the gate emitted them).
    pub src: usize,
    /// Destination GPU (hosting the chosen replica).
    pub dst: usize,
    /// Number of tokens in the range.
    pub tokens: u64,
}

/// Per-solve diagnostics (feeds Fig. 9 / Fig. 11).
#[derive(Clone, Debug, Default)]
pub struct ScheduleStats {
    /// simplex pivots spent
    pub lp_iterations: usize,
    /// dual-simplex pivots alone (the warm-repair work the long-step
    /// bound-flipping ratio test exists to cut)
    pub lp_dual_pivots: usize,
    /// nonbasic bound flips (primal flip steps + dual BFRT batch members)
    pub lp_bound_flips: usize,
    /// basis refactorizations inside the LP solve
    pub lp_refactors: usize,
    /// whether the warm path was taken
    pub warm: bool,
    /// LP objective (fractional optimal max GPU load, or comp+α·comm);
    /// `NaN` when no LP rung produced the plan
    pub lp_objective: f64,
    /// max GPU load after integer rounding
    pub max_gpu_load: u64,
    /// wall time of the LP solve + routing, nanoseconds
    pub solve_ns: u64,
    /// which rung of the degradation ladder produced this plan
    pub rung: crate::stats::DegradationRung,
    /// why a solve attempt ran out of [`crate::lp::SolveBudget`], when one
    /// did (the plan then came from a lower rung, or from the cold rung
    /// after a budget-exhausted warm attempt)
    pub budget_exhausted: Option<crate::lp::BudgetReason>,
    /// for fallback rungs: `(plan max load − LP lower bound) / LP lower
    /// bound`, the balance price of degrading; 0.0 on LP rungs
    pub fallback_excess: f64,
    /// decomposition meters when [`ScheduleMode::Decomposed`] produced the
    /// plan; `None` on the monolithic paths
    pub decompose: Option<decompose::DecomposeMeters>,
}

/// A complete per-micro-batch schedule.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// `replica_loads[e][r]` — integer tokens for replica `r` of expert `e`
    /// (aligned with `Placement::replicas[e]`).
    pub replica_loads: Vec<Vec<u64>>,
    /// Concrete token ranges realizing those loads.
    pub routes: Vec<Route>,
    /// Solve diagnostics.
    pub stats: ScheduleStats,
}

impl Schedule {
    /// Per-GPU compute loads implied by the replica assignment.
    pub fn gpu_loads(&self, placement: &Placement) -> Vec<u64> {
        let mut loads = vec![0u64; placement.num_gpus];
        for (e, grp) in placement.replicas.iter().enumerate() {
            for (r, &g) in grp.iter().enumerate() {
                loads[g] += self.replica_loads[e][r];
            }
        }
        loads
    }

    /// (send, recv) all-to-all volumes per GPU, in tokens (excludes
    /// locally-kept ranges).
    pub fn comm_volumes(&self, num_gpus: usize) -> (Vec<u64>, Vec<u64>) {
        let mut send = vec![0u64; num_gpus];
        let mut recv = vec![0u64; num_gpus];
        for r in &self.routes {
            if r.src != r.dst {
                send[r.src] += r.tokens;
                recv[r.dst] += r.tokens;
            }
        }
        (send, recv)
    }

    /// max/avg GPU-load imbalance ratio (Fig. 7's metric).
    pub fn imbalance(&self, placement: &Placement) -> f64 {
        let loads = self.gpu_loads(placement);
        let max = *loads.iter().max().unwrap() as f64;
        let avg = loads.iter().sum::<u64>() as f64 / loads.len() as f64;
        if avg == 0.0 {
            1.0
        } else {
            max / avg
        }
    }
}

/// Objective mode for the scheduling LP.
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleMode {
    /// LPP 1: minimize max GPU compute load.
    Compute,
    /// LPP 4: minimize `comp + alpha * comm` (Appendix A.1).
    CommAware { alpha: f64 },
    /// Topology-aware LPP (Appendix A.1): separate intra-node (alpha1) and
    /// inter-node (alpha2) communication weights.
    TopoAware { alpha1: f64, alpha2: f64 },
    /// Dantzig–Wolfe-style two-level decomposition of the scheduling LP
    /// ([`decompose`]): per-node-block subproblem LPs coordinated by a
    /// deterministic water-fill master, iterated until the max block load
    /// is within `tol` of the global fractional lower bound (or stalls).
    /// Needs a [`crate::topology::Topology`]; scales the solve to
    /// thousand-GPU groups where the monolithic LP blows the per-batch
    /// budget.
    Decomposed {
        /// Consecutive topology nodes merged into one subproblem block.
        nodes_per_block: usize,
        /// Cap on master/subproblem coordination rounds per micro-batch.
        max_outer_iters: usize,
        /// Relative gap-to-lower-bound (and stall) tolerance ending the
        /// outer loop early.
        tol: f64,
    },
}

impl ScheduleMode {
    /// Stable mode name used as a trace-span attribute (and matching the
    /// config vocabulary in [`crate::config`]).
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleMode::Compute => "compute",
            ScheduleMode::CommAware { .. } => "comm-aware",
            ScheduleMode::TopoAware { .. } => "topo-aware",
            ScheduleMode::Decomposed { .. } => "decomposed",
        }
    }
}

/// Scheduler options (each maps to a Fig. 11 ablation arm).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerOptions {
    /// Objective (LPP-1 / LPP-4 / topology-aware).
    pub mode: ScheduleMode,
    /// reuse the previous basis when only loads changed (§5.1)
    pub warm_start: bool,
    /// route local tokens to local replicas first (§5.2)
    pub locality_aware: bool,
    /// prefer same-node replicas in the second routing pass (App. A.1);
    /// requires a topology
    pub topo_aware_routing: bool,
    /// LP backend: the bounded-variable revised simplex (default: devex
    /// pricing, automatic factorization choice — see
    /// [`crate::lp::SolverKind`]) with its (pricing × factorization)
    /// engines selectable, or the dense tableau (`ablation_solvers`
    /// baseline)
    pub solver: crate::lp::SolverKind,
    /// How *multi-layer* consumers ([`crate::cluster::sim::MultiLayerSim`],
    /// the e2e trainer) execute the per-layer solves: the PR-1 round
    /// barrier ([`schedule_layers_parallel`], the default and ablation
    /// baseline), the persistent pipelined engine, or the engine with
    /// forecast-driven speculative pre-solves
    /// ([`crate::engine::EngineMode`]). Ignored by a single
    /// [`MicroEpScheduler`].
    pub engine: crate::engine::EngineMode,
    /// Per-solve resource budget threaded down to the LP backend. The
    /// default is unlimited, which keeps every solve bit-identical to a
    /// budget-free build; capped solves that exhaust degrade down the
    /// ladder (cold LP → greedy) instead of blocking the step.
    pub budget: crate::lp::SolveBudget,
    /// Deterministic fault-injection plan consulted at each `(step, layer)`
    /// — the chaos-test harness. `None` (the default, and the only value
    /// the config round-trip produces) injects nothing and adds no work.
    pub faults: Option<std::sync::Arc<crate::faults::FaultPlan>>,
    /// Structured-trace handle every consumer of these options records
    /// into ([`crate::obs::Tracer`]). Disabled by default (and the only
    /// value the config round-trip produces): recording is then a no-op,
    /// pinned bit-identical to an untraced build by
    /// `tests/trace_identity.rs`. Tracing observes, never steers — it must
    /// not change any schedule.
    pub trace: crate::obs::Tracer,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            mode: ScheduleMode::Compute,
            warm_start: true,
            locality_aware: true,
            topo_aware_routing: false,
            solver: crate::lp::SolverKind::default(),
            engine: crate::engine::EngineMode::Barrier,
            budget: crate::lp::SolveBudget::unlimited(),
            faults: None,
            trace: crate::obs::Tracer::default(),
        }
    }
}

pub use lpp::MicroEpScheduler;

/// Schedule many *independent* micro-batch problems — one per MoE layer or
/// per MicroEP group — concurrently with scoped threads.
///
/// Each [`MicroEpScheduler`] owns its warm-start state outright, so the
/// solves share nothing and results are bit-identical to the serial loop
/// (the §5.3 determinism requirement extends across layers). Work is split
/// into contiguous chunks over at most `available_parallelism` threads;
/// with one item (or one core) it degenerates to the serial path.
pub fn schedule_layers_parallel(
    scheds: &mut [MicroEpScheduler],
    loads: &[LoadMatrix],
) -> Vec<Schedule> {
    assert_eq!(scheds.len(), loads.len(), "one load matrix per scheduler");
    let n = scheds.len();
    let workers = std::thread::available_parallelism().map_or(1, |p| p.get()).min(n);
    if workers <= 1 {
        return scheds.iter_mut().zip(loads).map(|(s, lm)| s.schedule(lm)).collect();
    }
    let mut out: Vec<Option<Schedule>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for ((s_chunk, l_chunk), o_chunk) in scheds
            .chunks_mut(chunk)
            .zip(loads.chunks(chunk))
            .zip(out.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for ((s, lm), slot) in s_chunk.iter_mut().zip(l_chunk).zip(o_chunk.iter_mut()) {
                    *slot = Some(s.schedule(lm));
                }
            });
        }
    });
    out.into_iter().map(|s| s.expect("scheduler thread completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn parallel_layers_match_serial() {
        let p = cayley_graph_placement(8, 16);
        let layers = 6usize;
        let mk = || {
            (0..layers)
                .map(|_| MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default()))
                .collect::<Vec<_>>()
        };
        let mut par = mk();
        let mut ser = mk();
        for round in 0..4 {
            let loads: Vec<LoadMatrix> =
                (0..layers).map(|l| random_lm(round * 100 + l as u64, 16, 8, 1500)).collect();
            let a = schedule_layers_parallel(&mut par, &loads);
            let b: Vec<Schedule> =
                ser.iter_mut().zip(&loads).map(|(s, lm)| s.schedule(lm)).collect();
            for (l, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.replica_loads, y.replica_loads, "round {round} layer {l}");
                assert_eq!(x.routes, y.routes, "round {round} layer {l}");
            }
        }
    }

    #[test]
    fn parallel_single_layer_degenerates_to_serial() {
        let p = cayley_graph_placement(4, 8);
        let mut scheds = vec![MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default())];
        let loads = vec![random_lm(3, 8, 4, 400)];
        let out = schedule_layers_parallel(&mut scheds, &loads);
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].replica_loads.iter().map(|r| r.iter().sum::<u64>()).sum::<u64>(),
            loads[0].total()
        );
    }
}
