//! Greedy fallback planners — the sub-LP rungs of the degradation ladder.
//!
//! When both LP rungs fail (budget exhausted, numerical stall, poisoned
//! inputs), the scheduler must still emit a feasible plan *this step*. The
//! planners here are deterministic, allocation-light, and never fail:
//!
//! * [`greedy_fraction`] — least-loaded water-fill (~LPT): experts in
//!   descending-load order each spread their load over their replicas so
//!   the touched GPUs end at a common level. Provably within a factor
//!   `G_used / R_min` of the LP optimum (see below), and in practice far
//!   closer.
//! * [`passthrough_fraction`] — vanilla-EP passthrough: each expert's full
//!   load on its first replica, i.e. no balancing at all. The engine-level
//!   last resort when the scheduling workers themselves are gone.
//!
//! Both return the same `frac[e][r]` fractional-load matrix the LP path
//! produces, so the existing integer rounding
//! ([`super::rounding::round_replica_loads`], which conserves every
//! expert's total exactly) and token routing (Algorithm 1) run unchanged
//! downstream — a fallback plan is feasible by the same construction that
//! makes an LP plan feasible.
//!
//! # The proven approximation bound
//!
//! Let `T` be the batch's total tokens, `R_min = min_e |replicas(e)|`, and
//! `G_used` the number of GPUs hosting at least one replica. Water-filling
//! expert `e` either stays below an already-achieved GPU level (the max
//! does not grow) or raises *all* of `e`'s replicas to the common level
//! `(load_e + Σ prior load on replicas(e)) / |replicas(e)| ≤ T / R_min`.
//! Hence `greedy_max ≤ T / R_min`. The LP optimum is at least `T /
//! G_used` (all tokens land on the used GPUs), so
//!
//! ```text
//! greedy_max ≤ OPT_LP · G_used / R_min
//! ```
//!
//! — the bound `tests/chaos.rs`'s property test pins over the fuzz
//! instance generators.

use super::LoadMatrix;
use crate::placement::Placement;

/// Deterministic least-loaded water-fill. Experts are processed in
/// descending total-load order (ties by ascending index); each expert's
/// load is split over its replicas so the lowest-loaded host GPUs rise to
/// a common level. Returns the `frac[e][r]` matrix (absolute fractional
/// loads, aligned with `placement.replicas`), non-negative and summing to
/// each expert's total exactly up to floating error — the same contract
/// the LP solution path feeds into integer rounding.
///
/// `base` adds pre-existing per-GPU load (App. A.2 pipelining); pass `&[]`
/// for none.
pub fn greedy_fraction(placement: &Placement, loads: &LoadMatrix, base: &[u64]) -> Vec<Vec<f64>> {
    assert!(base.is_empty() || base.len() == placement.num_gpus);
    let mut gpu_load: Vec<f64> = if base.is_empty() {
        vec![0.0; placement.num_gpus]
    } else {
        base.iter().map(|&b| b as f64).collect()
    };
    let mut frac: Vec<Vec<f64>> = placement
        .replicas
        .iter()
        .map(|grp| vec![0.0; grp.len()])
        .collect();

    // descending load, ascending index — fully deterministic
    let mut order: Vec<usize> = (0..placement.num_experts).collect();
    order.sort_by_key(|&e| (std::cmp::Reverse(loads.expert_load(e)), e));

    for e in order {
        let load = loads.expert_load(e) as f64;
        if load == 0.0 {
            continue;
        }
        let hosts = &placement.replicas[e];
        // replicas sorted by current host load (ties by replica index)
        let mut by_load: Vec<usize> = (0..hosts.len()).collect();
        by_load.sort_by(|&a, &b| {
            gpu_load[hosts[a]]
                .partial_cmp(&gpu_load[hosts[b]])
                .unwrap()
                .then(a.cmp(&b))
        });
        // water-fill: bring the lowest j replicas to a common level, where
        // j is the largest prefix the load can lift to (at least) the next
        // replica's level
        let levels: Vec<f64> = by_load.iter().map(|&r| gpu_load[hosts[r]]).collect();
        let mut fill = levels.len();
        let mut prefix_sum = 0.0;
        for (j, &lv) in levels.iter().enumerate() {
            if j > 0 && j as f64 * lv - prefix_sum >= load {
                fill = j;
                break;
            }
            prefix_sum += lv;
        }
        let prefix: f64 = levels[..fill].iter().sum();
        let level = (load + prefix) / fill as f64;
        let mut assigned = 0.0;
        for (j, &r) in by_load[..fill].iter().enumerate() {
            let share = (level - levels[j]).max(0.0);
            frac[e][r] = share;
            gpu_load[hosts[r]] += share;
            assigned += share;
        }
        // absorb floating residue on the (now lowest-ish) first replica so
        // the expert's total is conserved exactly enough for rounding; when
        // a negative residue is clamped at zero, the level bookkeeping must
        // move by the clamped delta, not the raw residue, or later experts
        // water-fill against a phantom deficit on this GPU
        let residue = load - assigned;
        if residue != 0.0 {
            let r = by_load[0];
            absorb_residue(&mut frac[e][r], &mut gpu_load[hosts[r]], residue);
        }
    }
    frac
}

/// Fold a floating residue into one replica's share, clamping at zero, and
/// advance the host GPU's water level by exactly the clamped delta so the
/// level bookkeeping never drifts from the emitted `frac`.
fn absorb_residue(share: &mut f64, level: &mut f64, residue: f64) {
    let old = *share;
    let new = (old + residue).max(0.0);
    *share = new;
    *level += new - old;
}

/// Vanilla-EP passthrough plan: each expert's full load on its first
/// replica. No balancing — the always-available rung-3 plan.
pub fn passthrough_fraction(placement: &Placement, loads: &LoadMatrix) -> Vec<Vec<f64>> {
    (0..placement.num_experts)
        .map(|e| {
            let k = placement.replica_count(e);
            let mut row = vec![0.0; k];
            row[0] = loads.expert_load(e) as f64;
            row
        })
        .collect()
}

/// Lower bound on the LPP-1 optimum (fractional max GPU load):
/// `max(T / G_used, max_e load_e / |replicas(e)|)`. Used to price fallback
/// plans ([`crate::stats::DegradationStats::fallback_excess_sum`]) without
/// needing the LP to have solved.
pub fn lp_lower_bound(placement: &Placement, loads: &LoadMatrix) -> f64 {
    let mut used = vec![false; placement.num_gpus];
    for grp in &placement.replicas {
        for &g in grp {
            used[g] = true;
        }
    }
    let g_used = used.iter().filter(|&&u| u).count().max(1);
    let total = loads.total() as f64;
    let mut bound = total / g_used as f64;
    for e in 0..placement.num_experts {
        let per_replica = loads.expert_load(e) as f64 / placement.replica_count(e) as f64;
        bound = bound.max(per_replica);
    }
    bound
}

/// Relative excess of a plan's max GPU load over the LP lower bound
/// (`0.0` when the bound is zero — an empty batch has nothing to excess).
pub fn excess_over_bound(max_gpu_load: u64, lower_bound: f64) -> f64 {
    if lower_bound <= 0.0 {
        0.0
    } else {
        (max_gpu_load as f64 - lower_bound).max(0.0) / lower_bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;
    use crate::scheduler::rounding::round_replica_loads;

    fn ring4() -> Placement {
        Placement::from_replicas(4, vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]])
    }

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    fn gpu_loads_of(p: &Placement, frac: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; p.num_gpus];
        for (e, grp) in p.replicas.iter().enumerate() {
            for (r, &g) in grp.iter().enumerate() {
                out[g] += frac[e][r];
            }
        }
        out
    }

    #[test]
    fn greedy_conserves_and_stays_nonnegative() {
        let p = cayley_graph_placement(8, 16);
        for seed in 0..10 {
            let lm = random_lm(seed, 16, 8, 2000);
            let frac = greedy_fraction(&p, &lm, &[]);
            for e in 0..16 {
                let sum: f64 = frac[e].iter().sum();
                assert!(
                    (sum - lm.expert_load(e) as f64).abs() < 1e-6,
                    "seed {seed} expert {e}: {sum} vs {}",
                    lm.expert_load(e)
                );
                assert!(frac[e].iter().all(|&x| x >= 0.0), "seed {seed} expert {e}");
            }
            // rounding accepts the matrix and conserves exactly
            let rl = round_replica_loads(&frac, &lm.expert_loads());
            for e in 0..16 {
                assert_eq!(rl[e].iter().sum::<u64>(), lm.expert_load(e));
            }
        }
    }

    #[test]
    fn greedy_conserves_at_residue_magnifying_magnitudes() {
        // huge per-cell loads magnify the floating residue the absorb step
        // handles; conservation must hold to relative precision and the
        // frac-implied GPU loads must stay finite and non-negative
        let p = cayley_graph_placement(8, 16);
        for seed in 0..10 {
            let mut rng = Rng::new(900 + seed);
            let mut lm = LoadMatrix::zeros(16, 8);
            for _ in 0..200 {
                let e = rng.below(16) as usize;
                let g = rng.below(8) as usize;
                lm.add(e, g, rng.below(1 << 45) + 1);
            }
            let frac = greedy_fraction(&p, &lm, &[]);
            for e in 0..16 {
                let want = lm.expert_load(e) as f64;
                let sum: f64 = frac[e].iter().sum();
                assert!(
                    (sum - want).abs() <= 1e-9 * want.max(1.0),
                    "seed {seed} expert {e}: {sum} vs {want}"
                );
                assert!(frac[e].iter().all(|&x| x >= 0.0 && x.is_finite()));
            }
            let gl = gpu_loads_of(&p, &frac);
            assert!(gl.iter().all(|&x| x >= 0.0 && x.is_finite()), "seed {seed}: {gl:?}");
        }
    }

    #[test]
    fn residue_clamp_keeps_levels_in_sync_with_frac() {
        // the clamp path: a negative residue larger than the absorbing
        // share zeroes the share, and the level must move by the clamped
        // delta (-0.25 here), not the raw residue (-0.75)
        let mut share = 0.25;
        let mut level = 10.25;
        absorb_residue(&mut share, &mut level, -0.75);
        assert_eq!(share, 0.0);
        assert!((level - 10.0).abs() < 1e-12, "level {level} must drop by the old share only");
        // unclamped residues (either sign) pass straight through
        absorb_residue(&mut share, &mut level, 0.5);
        assert_eq!(share, 0.5);
        assert!((level - 10.5).abs() < 1e-12);
        absorb_residue(&mut share, &mut level, -0.125);
        assert_eq!(share, 0.375);
        assert!((level - 10.375).abs() < 1e-12);
    }

    #[test]
    fn greedy_balances_the_figure3c_example() {
        // loads 4,6,6,8 on the ring: the LP reaches all-6; greedy must be
        // within its proven bound and in fact lands at the optimum here
        let p = ring4();
        let mut lm = LoadMatrix::zeros(4, 4);
        for (e, &l) in [4u64, 6, 6, 8].iter().enumerate() {
            lm.set(e, 0, l);
        }
        let frac = greedy_fraction(&p, &lm, &[]);
        let gl = gpu_loads_of(&p, &frac);
        let max = gl.iter().cloned().fold(0.0, f64::max);
        assert!(max <= 6.0 + 1e-9, "greedy max {max}, loads {gl:?}");
    }

    #[test]
    fn greedy_respects_proven_bound() {
        let p = cayley_graph_placement(8, 16);
        let r_min = (0..16).map(|e| p.replica_count(e)).min().unwrap();
        for seed in 0..10 {
            let lm = random_lm(100 + seed, 16, 8, 3000);
            let frac = greedy_fraction(&p, &lm, &[]);
            let max = gpu_loads_of(&p, &frac).iter().cloned().fold(0.0, f64::max);
            assert!(
                max <= lm.total() as f64 / r_min as f64 + 1e-6,
                "seed {seed}: {max} > T/R_min"
            );
        }
    }

    #[test]
    fn greedy_accounts_for_base_loads() {
        // gpu 0 pre-loaded: greedy should steer away from it
        let p = ring4();
        let mut lm = LoadMatrix::zeros(4, 4);
        lm.set(1, 0, 10); // expert 1 on gpus {0,1}
        let frac = greedy_fraction(&p, &lm, &[100, 0, 0, 0]);
        assert_eq!(frac[1][0], 0.0, "all load should avoid the busy gpu");
        assert!((frac[1][1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn passthrough_puts_everything_on_first_replica() {
        let p = ring4();
        let lm = random_lm(7, 4, 4, 500);
        let frac = passthrough_fraction(&p, &lm);
        for e in 0..4 {
            assert_eq!(frac[e][0], lm.expert_load(e) as f64);
            assert!(frac[e][1..].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn lower_bound_and_excess() {
        let p = ring4();
        let lm = LoadMatrix::from_rows(vec![
            vec![4, 0, 0, 0],
            vec![6, 0, 0, 0],
            vec![6, 0, 0, 0],
            vec![8, 0, 0, 0],
        ]);
        let lb = lp_lower_bound(&p, &lm);
        assert!((lb - 6.0).abs() < 1e-9, "T/G = 24/4 = 6, got {lb}");
        assert_eq!(excess_over_bound(6, lb), 0.0);
        assert!((excess_over_bound(9, lb) - 0.5).abs() < 1e-9);
        assert_eq!(excess_over_bound(5, 0.0), 0.0);
    }
}
