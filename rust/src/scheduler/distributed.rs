//! Distributed scheduling across devices (§5.3).
//!
//! MicroMoE places an identical scheduler on every device: one all-gather
//! collects `input_e^g`, then each device runs the deterministic algorithm
//! independently — no scatter needed, and consistency holds because inputs,
//! algorithm, and tie-breaking are identical everywhere.
//!
//! This module simulates that: N independent scheduler instances (one per
//! device) fed through a modeled all-gather, with a checker asserting
//! bit-identical schedules. It also exposes the centralized alternative the
//! paper rejected, for the latency comparison (gather + scatter = two
//! synchronization points vs one).

use super::lpp::MicroEpScheduler;
use super::{LoadMatrix, Schedule, SchedulerOptions};
use crate::placement::Placement;
use crate::topology::Topology;

/// A fleet of per-device schedulers sharing one placement.
pub struct DistributedSchedulers {
    devices: Vec<MicroEpScheduler>,
}

/// Outcome of one distributed scheduling round.
pub struct DistributedRound {
    /// The (identical) schedule computed on every device.
    pub schedule: Schedule,
    /// Whether all devices agreed bit-for-bit (must be true; kept for
    /// fault-injection tests).
    pub consistent: bool,
}

impl DistributedSchedulers {
    /// One identical scheduler per device (§5.3).
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        num_devices: usize,
    ) -> Self {
        assert!(num_devices > 0);
        let devices = (0..num_devices)
            .map(|_| MicroEpScheduler::new(placement.clone(), topo.clone(), opts.clone()))
            .collect();
        DistributedSchedulers { devices }
    }

    /// Devices participating in the deterministic round.
    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Run one round: every device schedules the all-gathered loads
    /// independently; results are cross-checked. The check covers the
    /// *full* schedule each GPU would act on — replica loads, token
    /// routes, and the implied per-GPU compute — not just the replica
    /// split (two schedules can agree on loads yet route differently).
    pub fn round(&mut self, gathered: &LoadMatrix) -> DistributedRound {
        let mut schedules: Vec<Schedule> =
            self.devices.iter_mut().map(|d| d.schedule(gathered)).collect();
        let first = schedules.remove(0);
        let placement = &self.devices[0].placement;
        let first_gpu = first.gpu_loads(placement);
        let consistent = schedules.iter().all(|s| {
            s.replica_loads == first.replica_loads
                && s.routes == first.routes
                && s.gpu_loads(placement) == first_gpu
        });
        DistributedRound { schedule: first, consistent }
    }
}

/// Communication-operation counts for scheduler placement strategies
/// (§5.3's argument: distributed = 1 op, centralized = 2 ops).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedulerCommOps {
    /// Collectives on the critical path per micro-batch.
    pub collective_ops: usize,
}

/// §5.3 distributed execution: one all-gather per micro-batch.
pub fn distributed_comm_ops() -> SchedulerCommOps {
    SchedulerCommOps { collective_ops: 1 } // all-gather only
}

/// Centralized alternative: gather to device 0 plus a result scatter.
pub fn centralized_comm_ops() -> SchedulerCommOps {
    SchedulerCommOps { collective_ops: 2 } // gather + scatter
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;
    use crate::scheduler::ScheduleMode;

    fn random_loads(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn all_devices_agree_over_many_batches() {
        let p = cayley_graph_placement(8, 16);
        let mut fleet =
            DistributedSchedulers::new(p, None, SchedulerOptions::default(), 8);
        for batch in 0..15 {
            let lm = random_loads(batch, 16, 8, 1200);
            let round = fleet.round(&lm);
            assert!(round.consistent, "divergence at batch {batch}");
        }
    }

    #[test]
    fn agreement_holds_for_comm_aware_mode() {
        let p = cayley_graph_placement(4, 8);
        let opts = SchedulerOptions {
            mode: ScheduleMode::CommAware { alpha: 0.5 },
            ..Default::default()
        };
        let mut fleet = DistributedSchedulers::new(p, None, opts, 4);
        for batch in 0..8 {
            let lm = random_loads(100 + batch, 8, 4, 600);
            assert!(fleet.round(&lm).consistent);
        }
    }

    #[test]
    fn warm_state_stays_in_sync() {
        // warm-start state is per-device; determinism must survive it
        let p = cayley_graph_placement(8, 32);
        let mut fleet =
            DistributedSchedulers::new(p, None, SchedulerOptions::default(), 3);
        let mut lm = random_loads(7, 32, 8, 4000);
        for step in 0..10 {
            let round = fleet.round(&lm);
            assert!(round.consistent, "divergence at step {step}");
            // drift the loads slightly (correlated micro-batches)
            let mut rng = Rng::new(1000 + step);
            for _ in 0..50 {
                lm.add(rng.below(32) as usize, rng.below(8) as usize, 1);
            }
        }
    }

    #[test]
    fn decomposed_fleets_agree_bit_for_bit() {
        // §5.3 extended to the two-level path: the water-fill master and
        // the per-block subproblem solves (which fan out across threads)
        // must replicate bit-for-bit on every device. Seed rotates via
        // LP_FUZZ_SEED so CI sweeps fresh load patterns.
        let seed = crate::prop::fuzz_seed(0x5eed_dec0);
        let p = cayley_graph_placement(32, 64);
        let topo = Topology::new(32, 16, 2, 4);
        let opts = SchedulerOptions {
            mode: ScheduleMode::Decomposed { nodes_per_block: 2, max_outer_iters: 3, tol: 1e-3 },
            ..Default::default()
        };
        let mut fleet = DistributedSchedulers::new(p, Some(topo), opts, 5);
        for batch in 0..10 {
            let lm = random_loads(seed.wrapping_add(batch), 64, 32, 3000);
            let round = fleet.round(&lm);
            assert!(round.consistent, "divergence at batch {batch} (seed {seed})");
            let m = round.schedule.stats.decompose.expect("decomposed path taken");
            assert!(m.blocks > 1, "partition must be nontrivial");
        }
    }

    #[test]
    fn comm_op_counts_favor_distributed() {
        assert!(distributed_comm_ops().collective_ops < centralized_comm_ops().collective_ops);
    }
}
