//! Flow-based token scheduling — the paper's §9 (Discussion) suggestion of
//! "replacing the linear programming optimization with … algorithms for
//! reduced computational complexity" in latency-sensitive (inference)
//! deployments, built out as a first-class alternative solver.
//!
//! LPP 1 is a makespan-minimization transportation problem, so the optimal
//! *integer* max load `T*` is exactly `⌈m*⌉` (Eq.-3 density, rounded up):
//! feasibility of a candidate `T` is a bipartite max-flow question
//!
//! ```text
//! source -(load_e)-> expert e -(inf)-> GPU g in EDP(e) -(T)-> sink
//! ```
//!
//! and max-flow integrality gives integer replica loads directly — no
//! LP, no rounding step. We binary-search `T` with Dinic's algorithm;
//! monotonicity of feasibility in `T` makes the search exact.

use super::LoadMatrix;
use crate::placement::Placement;

/// Dinic max-flow on a small static graph.
struct Dinic {
    // adjacency: per node, list of edge ids; edges stored as (to, cap)
    // with xor-paired reverse edges
    to: Vec<usize>,
    cap: Vec<i64>,
    head: Vec<Vec<usize>>,
    level: Vec<i32>,
    iter: Vec<usize>,
}

impl Dinic {
    fn new(n: usize) -> Self {
        Dinic { to: Vec::new(), cap: Vec::new(), head: vec![Vec::new(); n], level: vec![0; n], iter: vec![0; n] }
    }

    fn add_edge(&mut self, from: usize, to: usize, cap: i64) -> usize {
        let id = self.to.len();
        self.to.push(to);
        self.cap.push(cap);
        self.head[from].push(id);
        self.to.push(from);
        self.cap.push(0);
        self.head[to].push(id + 1);
        id
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.iter_mut().for_each(|l| *l = -1);
        let mut q = std::collections::VecDeque::new();
        self.level[s] = 0;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in &self.head[u] {
                let v = self.to[e];
                if self.cap[e] > 0 && self.level[v] < 0 {
                    self.level[v] = self.level[u] + 1;
                    q.push_back(v);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, u: usize, t: usize, f: i64) -> i64 {
        if u == t {
            return f;
        }
        while self.iter[u] < self.head[u].len() {
            let e = self.head[u][self.iter[u]];
            let v = self.to[e];
            if self.cap[e] > 0 && self.level[v] == self.level[u] + 1 {
                let d = self.dfs(v, t, f.min(self.cap[e]));
                if d > 0 {
                    self.cap[e] -= d;
                    self.cap[e ^ 1] += d;
                    return d;
                }
            }
            self.iter[u] += 1;
        }
        0
    }

    fn max_flow(&mut self, s: usize, t: usize) -> i64 {
        let mut flow = 0;
        while self.bfs(s, t) {
            self.iter.iter_mut().for_each(|i| *i = 0);
            loop {
                let f = self.dfs(s, t, i64::MAX);
                if f == 0 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }
}

/// Result of the flow solve.
#[derive(Clone, Debug)]
pub struct FlowSchedule {
    /// optimal integer max GPU load (== ⌈Eq.-3 density⌉)
    pub max_load: u64,
    /// `replica_loads[e][r]` aligned with `Placement::replicas[e]`
    pub replica_loads: Vec<Vec<u64>>,
    /// feasibility probes spent in the binary search
    pub probes: usize,
}

/// Solve LPP 1 exactly over the integers via binary search + max-flow.
pub fn flow_schedule(placement: &Placement, loads: &LoadMatrix) -> FlowSchedule {
    let e_count = placement.num_experts;
    let g_count = placement.num_gpus;
    let expert_loads: Vec<u64> = (0..e_count).map(|e| loads.expert_load(e)).collect();
    let total: u64 = expert_loads.iter().sum();

    // search bounds: perfect balance .. single-expert-per-replica worst case
    let mut lo = total.div_ceil(g_count as u64);
    for e in 0..e_count {
        lo = lo.max(expert_loads[e].div_ceil(placement.replica_count(e) as u64));
    }
    let mut hi = {
        // all experts dumped on their first replica
        let mut v = vec![0u64; g_count];
        for e in 0..e_count {
            v[placement.replicas[e][0]] += expert_loads[e];
        }
        *v.iter().max().unwrap_or(&0)
    };

    let build = |cap_t: u64| -> (Dinic, Vec<Vec<usize>>) {
        // nodes: 0 = source, 1..=E experts, E+1..=E+G gpus, E+G+1 sink
        let s = 0usize;
        let t = e_count + g_count + 1;
        let mut d = Dinic::new(t + 1);
        let mut edge_ids = vec![Vec::new(); e_count];
        for e in 0..e_count {
            d.add_edge(s, 1 + e, expert_loads[e] as i64);
            for &g in &placement.replicas[e] {
                let id = d.add_edge(1 + e, 1 + e_count + g, i64::MAX / 4);
                edge_ids[e].push(id);
            }
        }
        for g in 0..g_count {
            d.add_edge(1 + e_count + g, t, cap_t as i64);
        }
        (d, edge_ids)
    };

    let feasible = |cap_t: u64| -> bool {
        let (mut d, _) = build(cap_t);
        d.max_flow(0, e_count + g_count + 1) as u64 == total
    };

    let mut probes = 0usize;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        probes += 1;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    // final solve at T* to extract integral replica loads
    let (mut d, edge_ids) = build(lo);
    let got = d.max_flow(0, e_count + g_count + 1) as u64;
    debug_assert_eq!(got, total, "optimal T must be feasible");
    let replica_loads = (0..e_count)
        .map(|e| {
            edge_ids[e]
                .iter()
                .map(|&id| d.cap[id ^ 1] as u64) // flow == reverse residual
                .collect()
        })
        .collect();
    FlowSchedule { max_load: lo, replica_loads, probes: probes + 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::prop::forall;
    use crate::rng::Rng;
    use crate::scheduler::{MicroEpScheduler, SchedulerOptions};

    fn random_inputs(rng: &mut Rng, e: usize, g: usize, tokens: u64) -> LoadMatrix {
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..tokens {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn figure3c_flow_matches_paper() {
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]],
        );
        let mut lm = LoadMatrix::zeros(4, 4);
        for (e, l) in [(0usize, 4u64), (1, 6), (2, 6), (3, 8)] {
            lm.set(e, 0, l);
        }
        let f = flow_schedule(&p, &lm);
        assert_eq!(f.max_load, 6);
        for e in 0..4 {
            assert_eq!(f.replica_loads[e].iter().sum::<u64>(), lm.expert_load(e));
        }
    }

    #[test]
    fn flow_equals_ceil_of_lp_objective() {
        forall("flow == ceil(LP)", 80, |rng, _| {
            let g = 4 + 2 * (rng.below(3) as usize);
            let e = g * (1 + rng.below(2) as usize); // E·2 divides G
            let p = crate::placement::random::random_placement(g, e, 2, rng);
            let lm = random_inputs(rng, p.num_experts, g, 400);
            let f = flow_schedule(&p, &lm);
            let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
            let lp = s.schedule(&lm).stats.lp_objective;
            let expect = lp.ceil() as u64;
            // fp guard: lp may sit a hair above an integer
            let expect = if (lp - lp.round()).abs() < 1e-6 { lp.round() as u64 } else { expect };
            assert_eq!(f.max_load, expect, "flow {} vs LP {}", f.max_load, lp);
        });
    }

    #[test]
    fn flow_loads_realize_claimed_makespan() {
        forall("flow realizes T*", 60, |rng, _| {
            let p = cayley_graph_placement(8, 16);
            let lm = random_inputs(rng, 16, 8, 1000);
            let f = flow_schedule(&p, &lm);
            let mut gpu = vec![0u64; 8];
            for (e, grp) in p.replicas.iter().enumerate() {
                assert_eq!(
                    f.replica_loads[e].iter().sum::<u64>(),
                    lm.expert_load(e),
                    "conservation for expert {e}"
                );
                for (r, &g) in grp.iter().enumerate() {
                    gpu[g] += f.replica_loads[e][r];
                }
            }
            assert_eq!(*gpu.iter().max().unwrap(), f.max_load);
        });
    }

    #[test]
    fn empty_loads() {
        let p = cayley_graph_placement(4, 8);
        let lm = LoadMatrix::zeros(8, 4);
        let f = flow_schedule(&p, &lm);
        assert_eq!(f.max_load, 0);
    }

    #[test]
    fn single_hot_expert_splits_evenly() {
        let p = Placement::from_replicas(4, vec![vec![0, 1], vec![2, 3]]);
        let mut lm = LoadMatrix::zeros(2, 4);
        lm.set(0, 0, 100);
        let f = flow_schedule(&p, &lm);
        assert_eq!(f.max_load, 50);
        assert_eq!(f.replica_loads[0], vec![50, 50]);
    }
}
