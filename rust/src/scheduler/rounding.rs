//! Fractional → integer replica loads, expert-total preserving.
//!
//! The LP yields fractional `x_e^g`; tokens are indivisible. Largest-
//! remainder rounding per expert keeps `Σ_r x_e^r == load_e` exactly and
//! perturbs any GPU's load by less than the number of its resident experts
//! — negligible against micro-batch token counts (tested).

/// Round each expert's fractional replica loads to integers summing to
/// `totals[e]`.
pub fn round_replica_loads(frac: &[Vec<f64>], totals: &[u64]) -> Vec<Vec<u64>> {
    assert_eq!(frac.len(), totals.len());
    frac.iter()
        .zip(totals)
        .map(|(xs, &total)| round_preserving_sum(xs, total))
        .collect()
}

/// Largest-remainder rounding of `xs` to integers summing to `total`.
pub fn round_preserving_sum(xs: &[f64], total: u64) -> Vec<u64> {
    if xs.is_empty() {
        assert_eq!(total, 0, "no replicas to hold {total} tokens");
        return Vec::new();
    }
    let mut out: Vec<u64> = xs.iter().map(|&x| x.max(0.0).floor() as u64).collect();
    let mut assigned: u64 = out.iter().sum();
    // floor sum can exceed `total` only via fp noise on the LP solution;
    // shave from the largest entries
    while assigned > total {
        let i = out
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        out[i] -= 1;
        assigned -= 1;
    }
    // distribute the remainder by largest fractional part
    let mut rem: Vec<(usize, f64)> = xs
        .iter()
        .enumerate()
        .map(|(i, &x)| (i, x.max(0.0) - x.max(0.0).floor()))
        .collect();
    rem.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut left = total - assigned;
    let mut k = 0usize;
    while left > 0 {
        out[rem[k % rem.len()].0] += 1;
        left -= 1;
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn exact_integers_pass_through() {
        assert_eq!(round_preserving_sum(&[3.0, 5.0, 2.0], 10), vec![3, 5, 2]);
    }

    #[test]
    fn remainder_goes_to_largest_fraction() {
        assert_eq!(round_preserving_sum(&[2.7, 3.2, 4.1], 10), vec![3, 3, 4]);
    }

    #[test]
    fn sum_always_preserved() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let n = 1 + rng.below(6) as usize;
            let total = rng.below(1000);
            // random fractional split of `total`
            let mut parts: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s: f64 = parts.iter().sum();
            for p in &mut parts {
                *p = *p / s * total as f64;
            }
            let out = round_preserving_sum(&parts, total);
            assert_eq!(out.iter().sum::<u64>(), total);
            // each entry within 1 of its fractional value
            for (o, p) in out.iter().zip(&parts) {
                assert!((*o as f64 - p).abs() < 1.0 + 1e-9, "{o} vs {p}");
            }
        }
    }

    #[test]
    fn zero_total() {
        assert_eq!(round_preserving_sum(&[0.0, 0.0], 0), vec![0, 0]);
    }

    #[test]
    fn fp_noise_above_total_is_shaved() {
        // floors sum to 11 > total 10 (simulated fp contamination)
        assert_eq!(round_preserving_sum(&[6.0, 5.0], 10).iter().sum::<u64>(), 10);
    }

    #[test]
    fn negative_noise_clamped() {
        let out = round_preserving_sum(&[-1e-9, 5.0], 5);
        assert_eq!(out, vec![0, 5]);
    }
}
