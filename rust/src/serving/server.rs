//! The batching-window serving loop and the closed-loop bench runner.
//!
//! [`MoeServer`] consumes an open-loop request trace: it collects pending
//! requests for `window_us` (or until `max_batch` are queued, whichever
//! comes first), sheds requests that have been queued past
//! `shed_after_us`, scatters the survivors' decode tokens over a drifting
//! [`TopicMix`] into a single-layer micro-batch, drives any registered
//! [`crate::balancer::Balancer`] policy through the [`MoeSession`] facade,
//! and charges solve + dispatch latency against each request's SLO.
//!
//! The virtual clock is **serial**: the next window opens only after the
//! previous window's service completes, so sustained overload builds a
//! queue and (with a finite `shed_after_us`) triggers admission shedding —
//! the open-loop behaviour the serving benches measure. Every decision the
//! loop makes (admit, close, shed, miss) is a pure function of the request
//! trace and the config whenever [`SolveCost::Virtual`] is selected, which
//! is what the determinism and golden-serving suites pin; keep the loop's
//! arithmetic in lock-step with `python/tools/serving_reference.py`.

use std::collections::VecDeque;
use std::time::Instant;

use crate::balancer::{MoeLayerPlan, MoeSession};
use crate::cluster::sim::moe_layer_time;
use crate::cluster::CostModel;
use crate::scheduler::{LoadMatrix, Route};
use crate::topology::Topology;
use crate::workload::TopicMix;

use super::arrivals::Request;
use super::sla::SlaStats;

/// How scheduling latency is charged against the SLO.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SolveCost {
    /// Charge a fixed virtual latency per window — the deterministic mode
    /// every reproducibility suite uses (the clock advance is then a pure
    /// function of the trace).
    Virtual {
        /// Charged scheduling latency per non-empty window, µs.
        us: f64,
    },
    /// Charge the measured wall time of the policy's solve — what the
    /// serving benches use to compare real scheduling overheads.
    Wall,
}

/// How dispatch + expert-compute + combine latency is charged.
#[derive(Clone, Debug)]
pub enum DispatchCost {
    /// Affine in the window's token count — deterministic and mirrored by
    /// the Python serving reference.
    PerToken {
        /// Fixed per-window overhead, µs.
        fixed_us: f64,
        /// Marginal cost per routed token, µs.
        us_per_token: f64,
    },
    /// The cluster cost model's per-GPU breakdown for the emitted plan
    /// (`dispatch + compute + combine` of
    /// [`crate::cluster::sim::moe_layer_time`]) — this is where a
    /// better-balanced plan directly buys latency.
    Modeled {
        /// Cluster cost model.
        model: CostModel,
        /// Topology (node boundaries for the all-to-all model).
        topo: Topology,
    },
}

/// Batching-window server configuration.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Maximum time a window stays open collecting requests, µs (≥ 1).
    pub window_us: f64,
    /// Maximum requests per window's micro-batch (≥ 1).
    pub max_batch: usize,
    /// End-to-end deadline per request, µs.
    pub slo_us: f64,
    /// Admission control: shed a request whose queue wait at window close
    /// exceeds this, µs (`f64::INFINITY` = never shed).
    pub shed_after_us: f64,
    /// Scheduling-latency charge.
    pub solve_cost: SolveCost,
    /// Dispatch/compute/combine-latency charge.
    pub dispatch_cost: DispatchCost,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            window_us: 500.0,
            max_batch: 32,
            slo_us: 5_000.0,
            shed_after_us: f64::INFINITY,
            solve_cost: SolveCost::Virtual { us: 64.0 },
            dispatch_cost: DispatchCost::PerToken { fixed_us: 32.0, us_per_token: 0.0625 },
        }
    }
}

/// What one batching window did (the determinism suite compares these
/// bit-for-bit; solve wall time is excluded by construction — only the
/// *charged* latencies appear).
#[derive(Clone, Debug, PartialEq)]
pub struct WindowRecord {
    /// Window index (0-based).
    pub index: u64,
    /// Virtual time the window opened, µs.
    pub open_us: f64,
    /// Virtual time the window closed and the batch dispatched, µs.
    pub close_us: f64,
    /// Ids served in this window's micro-batch, FIFO order.
    pub served: Vec<u64>,
    /// Ids shed at this window's close.
    pub shed: Vec<u64>,
    /// Total decode tokens in the micro-batch.
    pub tokens: u64,
    /// The emitted plan's per-GPU compute loads (empty for empty windows).
    pub gpu_compute: Vec<u64>,
    /// The emitted plan's token routes (empty for empty windows).
    pub routes: Vec<Route>,
    /// Charged scheduling latency, µs.
    pub solve_us: f64,
    /// Charged dispatch + compute + combine latency, µs.
    pub dispatch_us: f64,
}

/// Full per-window record of one [`MoeServer::run`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServingTrace {
    /// One record per formed window, in virtual-time order.
    pub windows: Vec<WindowRecord>,
}

/// Open-loop batching-window server over any registered policy.
pub struct MoeServer {
    session: MoeSession,
    cfg: ServingConfig,
    mix: TopicMix,
    gpus: usize,
    sla: SlaStats,
    now_us: f64,
    windows: u64,
}

impl MoeServer {
    /// Server over a single-layer session. Panics if the session schedules
    /// more than one layer (serving forms single-layer decode batches) or
    /// the config is degenerate.
    pub fn new(session: MoeSession, cfg: ServingConfig, mix: TopicMix) -> Self {
        assert_eq!(session.layers(), 1, "serving drives single-layer decode sessions");
        assert_eq!(mix.num_experts(), session.experts(), "mix/session expert counts differ");
        assert!(cfg.window_us >= 1.0, "window must be at least 1 us");
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(cfg.slo_us >= 0.0 && cfg.shed_after_us >= 0.0, "negative SLO bounds");
        let gpus = session.gpus();
        MoeServer { session, cfg, mix, gpus, sla: SlaStats::default(), now_us: 0.0, windows: 0 }
    }

    /// Serve a request trace (sorted by arrival) to completion: every
    /// request ends up served or shed. Returns the per-window trace;
    /// cumulative SLO accounting accrues in [`MoeServer::sla`].
    pub fn run(&mut self, reqs: &[Request]) -> ServingTrace {
        assert!(
            reqs.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
            "request trace must be sorted by arrival time"
        );
        let n = reqs.len();
        self.sla.arrived += n as u64;
        // clone of the session's tracing handle (shared buffer): window
        // spans land on the virtual timeline via record_at, and the clock
        // is advanced so solve spans from a Virtual-clock tracer stamp at
        // the window close. Disabled tracers make all of this a no-op.
        let obs = self.session.tracer().clone();
        let mut trace = ServingTrace::default();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut i = 0usize;
        while i < n || !queue.is_empty() {
            // admit everything that arrived while the last window served
            while i < n && reqs[i].arrival_us <= self.now_us {
                queue.push_back(i);
                i += 1;
            }
            if queue.is_empty() {
                // idle: jump the clock to the next arrival
                self.now_us = reqs[i].arrival_us;
                continue;
            }
            let open_us = self.now_us;
            let mut close_us = open_us + self.cfg.window_us;
            // collect during the window, closing early once max_batch are
            // pending
            while queue.len() < self.cfg.max_batch && i < n && reqs[i].arrival_us <= close_us {
                queue.push_back(i);
                i += 1;
            }
            if queue.len() >= self.cfg.max_batch {
                // filled early: close at the arrival that filled it (a
                // pre-existing backlog closes the window immediately)
                close_us = open_us.max(reqs[queue[self.cfg.max_batch - 1]].arrival_us);
            }
            // shed the ENTIRE stale prefix at close — the queue is in
            // arrival order, so every request whose wait exceeds
            // shed_after_us sits at the front; examining only requests
            // popped toward the batch would let a stale request survive
            // the close that already condemned it whenever the batch fills
            // first. Then take the batch FIFO from the fresh remainder.
            let mut shed: Vec<u64> = Vec::new();
            while let Some(&j) = queue.front() {
                if close_us - reqs[j].arrival_us > self.cfg.shed_after_us {
                    queue.pop_front();
                    shed.push(reqs[j].id);
                    self.sla.record_shed();
                } else {
                    break;
                }
            }
            let mut batch: Vec<usize> = Vec::new();
            while batch.len() < self.cfg.max_batch {
                let Some(j) = queue.pop_front() else { break };
                batch.push(j);
            }

            self.sla.windows += 1;
            let index = self.windows;
            self.windows += 1;
            obs.set_virtual_us(close_us);
            let (tokens, gpu_compute, routes, solve_us, dispatch_us) = if batch.is_empty() {
                self.sla.empty_windows += 1;
                (0u64, Vec::new(), Vec::new(), 0.0, 0.0)
            } else {
                self.mix.next_window();
                let mut lm = LoadMatrix::zeros(self.session.experts(), self.gpus);
                let mut tokens = 0u64;
                for &j in &batch {
                    let r = &reqs[j];
                    // requests pin to source GPUs round-robin by id
                    let gpu = (r.id % self.gpus as u64) as usize;
                    self.mix.scatter(&mut lm, gpu, r.tokens);
                    tokens += r.tokens;
                }
                let t0 = Instant::now();
                let out = self.session.step(std::slice::from_ref(&lm));
                let wall_us = t0.elapsed().as_secs_f64() * 1e6;
                let plan = &out.layers[0];
                let solve_us = match self.cfg.solve_cost {
                    SolveCost::Virtual { us } => us,
                    SolveCost::Wall => wall_us,
                };
                let dispatch_us = dispatch_charge(&self.cfg.dispatch_cost, tokens, plan);
                (tokens, plan.gpu_compute.clone(), plan.routes.clone(), solve_us, dispatch_us)
            };
            let service_us = solve_us + dispatch_us;
            let mut misses = 0usize;
            for &j in &batch {
                let wait = close_us - reqs[j].arrival_us;
                if self.sla.record_served(wait, solve_us, dispatch_us, self.cfg.slo_us) {
                    misses += 1;
                }
            }
            obs.record_at(
                open_us,
                (close_us - open_us) + service_us,
                crate::obs::Span::ServingWindow {
                    index: index as usize,
                    admitted: batch.len(),
                    shed: shed.len(),
                    deadline_miss: misses,
                },
            );
            trace.windows.push(WindowRecord {
                index,
                open_us,
                close_us,
                served: batch.iter().map(|&j| reqs[j].id).collect(),
                shed,
                tokens,
                gpu_compute,
                routes,
                solve_us,
                dispatch_us,
            });
            // serial server: the next window opens after service completes
            self.now_us = close_us + service_us;
        }
        trace
    }

    /// Cumulative SLO accounting.
    pub fn sla(&self) -> &SlaStats {
        &self.sla
    }

    /// The policy session being driven.
    pub fn session(&self) -> &MoeSession {
        &self.session
    }

    /// Current virtual time, µs.
    pub fn now_us(&self) -> f64 {
        self.now_us
    }
}

fn dispatch_charge(cost: &DispatchCost, tokens: u64, plan: &MoeLayerPlan) -> f64 {
    match cost {
        DispatchCost::PerToken { fixed_us, us_per_token } => {
            fixed_us + us_per_token * tokens as f64
        }
        DispatchCost::Modeled { model, topo } => {
            let bd = moe_layer_time(model, topo, plan);
            // solve latency is charged separately; take the data-path legs
            (bd.dispatch + bd.compute + bd.combine) * 1e6
        }
    }
}

/// Closed-loop driver: feeds each micro-batch as soon as the previous one
/// completes (no arrival process, no queueing — the classic closed-loop
/// complement to [`MoeServer`]'s open loop) and meters per-batch solve and
/// modeled dispatch latency into the same [`SlaStats`]. Benches and
/// examples use this instead of hand-rolling `session.step` timing loops.
pub struct ServingRunner {
    session: MoeSession,
    dispatch_cost: Option<DispatchCost>,
    slo_us: f64,
    sla: SlaStats,
}

impl ServingRunner {
    /// Closed-loop runner over a single-layer session; dispatch latency is
    /// not charged until [`ServingRunner::with_dispatch`] installs a model.
    /// Panics if the session schedules more than one layer — the runner
    /// meters one plan per batch, so a multi-layer session would silently
    /// drop every layer past the first.
    pub fn new(session: MoeSession) -> Self {
        assert_eq!(session.layers(), 1, "serving drives single-layer decode sessions");
        ServingRunner { session, dispatch_cost: None, slo_us: f64::INFINITY, sla: SlaStats::default() }
    }

    /// Charge dispatch latency per batch under the given model.
    pub fn with_dispatch(mut self, cost: DispatchCost) -> Self {
        self.dispatch_cost = Some(cost);
        self
    }

    /// Count batches whose solve + dispatch latency exceeds `slo_us` as
    /// deadline misses.
    pub fn with_slo_us(mut self, slo_us: f64) -> Self {
        self.slo_us = slo_us;
        self
    }

    /// Feed one micro-batch, metering wall solve latency (and dispatch, if
    /// a model is installed) into [`ServingRunner::sla`].
    pub fn step(&mut self, lm: &LoadMatrix) -> MoeLayerPlan {
        self.sla.arrived += 1;
        self.sla.windows += 1;
        let t0 = Instant::now();
        let out = self.session.step(std::slice::from_ref(lm));
        let solve_us = t0.elapsed().as_secs_f64() * 1e6;
        let plan = out.layers.into_iter().next().expect("single-layer step");
        let dispatch_us = match &self.dispatch_cost {
            Some(cost) => dispatch_charge(cost, lm.total(), &plan),
            None => 0.0,
        };
        self.sla.record_served(0.0, solve_us, dispatch_us, self.slo_us);
        plan
    }

    /// Feed every batch in order, returning the emitted plans.
    pub fn run(&mut self, batches: &[LoadMatrix]) -> Vec<MoeLayerPlan> {
        batches.iter().map(|lm| self.step(lm)).collect()
    }

    /// Per-batch latency accounting (queue is always zero: closed loop).
    pub fn sla(&self) -> &SlaStats {
        &self.sla
    }

    /// The session being driven.
    pub fn session(&self) -> &MoeSession {
        &self.session
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::arrivals::{ArrivalGen, ArrivalProcess, TokenModel};
    use crate::topology::Topology;

    fn session(policy: &str) -> MoeSession {
        MoeSession::builder()
            .topology(Topology::new(8, 4, 2, 8))
            .experts(16)
            .policy_name(policy)
            .build()
            .unwrap()
    }

    fn poisson_reqs(n: usize, rate_hz: f64, seed: u64) -> Vec<Request> {
        ArrivalGen::new(ArrivalProcess::Poisson { rate_hz }, TokenModel::Fixed(32), seed).take(n)
    }

    #[test]
    fn serves_every_request_exactly_once() {
        let cfg = ServingConfig::default();
        let mut server = session("vanilla-ep").serve(cfg.clone(), TopicMix::new(16, 1.1, 4, 5));
        let reqs = poisson_reqs(300, 20_000.0, 11);
        let trace = server.run(&reqs);
        let sla = server.sla();
        assert_eq!(sla.arrived, 300);
        assert_eq!(sla.served, 300);
        assert_eq!(sla.shed, 0);
        assert_eq!(sla.accounted(), 300);
        let mut seen: Vec<u64> = trace.windows.iter().flat_map(|w| w.served.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..300).collect::<Vec<_>>());
        for w in &trace.windows {
            assert!(w.served.len() <= cfg.max_batch, "window {} overfull", w.index);
            assert_eq!(w.gpu_compute.iter().sum::<u64>(), w.tokens, "plan lost tokens");
        }
    }

    #[test]
    fn overload_sheds_under_tight_admission() {
        let cfg = ServingConfig {
            shed_after_us: 2_000.0,
            solve_cost: SolveCost::Virtual { us: 4_000.0 },
            ..Default::default()
        };
        let mut server = session("vanilla-ep").serve(cfg, TopicMix::new(16, 1.1, 4, 5));
        // arrivals far faster than the 4ms-per-window service rate
        let reqs = poisson_reqs(400, 100_000.0, 13);
        server.run(&reqs);
        let sla = server.sla();
        assert!(sla.shed > 0, "overload must shed: {sla:?}");
        assert_eq!(sla.accounted(), 400, "conservation under shedding");
    }

    #[test]
    #[should_panic(expected = "single-layer decode sessions")]
    fn closed_loop_runner_rejects_multi_layer_sessions() {
        // without the assert a 2-layer session would meter layer 0 and
        // silently drop layer 1's plan on every step
        let session = MoeSession::builder()
            .topology(Topology::new(8, 4, 2, 8))
            .experts(16)
            .policy_name("micromoe")
            .layers(2)
            .build()
            .unwrap();
        let _ = ServingRunner::new(session);
    }

    #[test]
    fn stale_backlog_is_shed_in_full_at_window_close() {
        // 40 requests burst in at t=0; service is slow (4ms/window) and
        // shed_after is tight (1ms). From the second window on, the whole
        // backlog is stale at close: every close must shed its entire
        // stale prefix, never strand one behind a filled batch.
        let cfg = ServingConfig {
            max_batch: 8,
            shed_after_us: 1_000.0,
            solve_cost: SolveCost::Virtual { us: 4_000.0 },
            ..Default::default()
        };
        let mut server = session("vanilla-ep").serve(cfg.clone(), TopicMix::new(16, 1.1, 4, 5));
        let reqs: Vec<Request> = (0..40)
            .map(|id| Request { id, arrival_us: 0.0, tokens: 16 })
            .collect();
        let trace = server.run(&reqs);
        let sla = server.sla();
        assert_eq!(sla.accounted(), 40, "conservation under shedding");
        assert!(sla.shed > 0, "stale backlog must shed: {sla:?}");
        for w in &trace.windows {
            // after a close, no request left queued may already be stale
            // at that close — it would have to survive into the next
            // window with an even longer wait
            for later in &trace.windows[w.index as usize + 1..] {
                for &id in &later.served {
                    let wait = w.close_us - reqs[id as usize].arrival_us;
                    assert!(
                        wait <= cfg.shed_after_us,
                        "request {id} was stale at window {} close but served later",
                        w.index
                    );
                }
            }
        }
    }

    #[test]
    fn closed_loop_runner_meters_every_batch() {
        let mut runner = ServingRunner::new(session("micromoe")).with_slo_us(f64::INFINITY);
        let mut lm = LoadMatrix::zeros(16, 8);
        for g in 0..8 {
            lm.add(g % 16, g, 100);
        }
        let plans = runner.run(&[lm.clone(), lm.clone(), lm]);
        assert_eq!(plans.len(), 3);
        let sla = runner.sla();
        assert_eq!(sla.served, 3);
        assert_eq!(sla.deadline_misses, 0);
        assert_eq!(sla.queue.count(), 3);
        assert!(sla.queue.samples().iter().all(|&q| q == 0.0), "closed loop has no queueing");
        assert!(sla.solve.mean() > 0.0, "wall solve latency metered");
    }
}
