//! SLO accounting for the serving tier: per-request latency breakdown
//! (queue / solve / dispatch), exact and P² streaming percentiles, and the
//! deadline-miss / shed counters the serving benches report.
//!
//! All latencies are in virtual microseconds. When the server runs with
//! [`crate::serving::SolveCost::Virtual`] the whole accumulator is a pure
//! function of the request trace and the server config — that is what lets
//! the determinism suite demand bit-identical [`SlaStats`] across runs and
//! engine worker counts, and the golden fixture replay them from Python.

use crate::ser::Json;
use crate::stats::LatencyTrack;

/// Cumulative serving-tier SLO accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlaStats {
    /// Requests that entered the server's queue.
    pub arrived: u64,
    /// Requests served in some window's micro-batch.
    pub served: u64,
    /// Requests shed by admission control (queued past `shed_after_us`).
    pub shed: u64,
    /// Served requests whose end-to-end latency exceeded `slo_us`.
    pub deadline_misses: u64,
    /// Batching windows formed (including emptied-by-shedding ones).
    pub windows: u64,
    /// Windows whose batch was empty after shedding (no plan emitted).
    pub empty_windows: u64,
    /// Per-request time spent queued before its window closed, µs.
    pub queue: LatencyTrack,
    /// Per-request (= per-window) scheduling latency, µs.
    pub solve: LatencyTrack,
    /// Per-request (= per-window) dispatch + compute + combine latency, µs.
    pub dispatch: LatencyTrack,
    /// Per-request end-to-end latency (queue + solve + dispatch), µs.
    pub e2e: LatencyTrack,
}

impl SlaStats {
    /// Record one served request's latency breakdown against deadline
    /// `slo_us`, returning whether it missed.
    pub fn record_served(
        &mut self,
        queue_us: f64,
        solve_us: f64,
        dispatch_us: f64,
        slo_us: f64,
    ) -> bool {
        self.served += 1;
        let e2e = queue_us + solve_us + dispatch_us;
        self.queue.record(queue_us);
        self.solve.record(solve_us);
        self.dispatch.record(dispatch_us);
        self.e2e.record(e2e);
        let miss = e2e > slo_us;
        if miss {
            self.deadline_misses += 1;
        }
        miss
    }

    /// Record one shed request.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Requests accounted for (served or shed).
    pub fn accounted(&self) -> u64 {
        self.served + self.shed
    }

    /// Deadline misses over served requests (0 when nothing was served).
    pub fn miss_rate(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.served as f64
        }
    }

    /// Shed requests over arrived requests (0 before the first arrival).
    pub fn shed_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.shed as f64 / self.arrived as f64
        }
    }

    /// JSON report (what the serving bench uploads as a CI artifact):
    /// counters plus exact and P² p50/p95/p99 for every latency component.
    pub fn to_json(&self) -> Json {
        fn track(t: &LatencyTrack) -> Json {
            // JSON has no NaN; empty tracks report null (crate-wide guard)
            let num = Json::num;
            Json::obj(vec![
                ("count", Json::Num(t.count() as f64)),
                ("mean_us", num(t.mean())),
                ("max_us", num(t.max())),
                ("p50_us", num(t.exact(0.50))),
                ("p95_us", num(t.exact(0.95))),
                ("p99_us", num(t.exact(0.99))),
                ("p2_p50_us", num(t.p2_p50())),
                ("p2_p95_us", num(t.p2_p95())),
                ("p2_p99_us", num(t.p2_p99())),
            ])
        }
        Json::obj(vec![
            ("arrived", Json::Num(self.arrived as f64)),
            ("served", Json::Num(self.served as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_misses", Json::Num(self.deadline_misses as f64)),
            ("windows", Json::Num(self.windows as f64)),
            ("empty_windows", Json::Num(self.empty_windows as f64)),
            ("miss_rate", Json::Num(self.miss_rate())),
            ("shed_rate", Json::Num(self.shed_rate())),
            ("queue", track(&self.queue)),
            ("solve", track(&self.solve)),
            ("dispatch", track(&self.dispatch)),
            ("e2e", track(&self.e2e)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_served_breaks_down_and_flags_misses() {
        let mut s = SlaStats::default();
        s.arrived = 3;
        assert!(!s.record_served(10.0, 5.0, 20.0, 100.0));
        assert!(s.record_served(80.0, 5.0, 20.0, 100.0), "105 > 100 misses");
        s.record_shed();
        assert_eq!(s.served, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.accounted(), 3);
        assert!((s.miss_rate() - 0.5).abs() < 1e-12);
        assert!((s.shed_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.e2e.count(), 2);
        assert!((s.e2e.max() - 105.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_have_benign_rates_and_json() {
        let s = SlaStats::default();
        assert_eq!(s.miss_rate(), 0.0);
        assert_eq!(s.shed_rate(), 0.0);
        let j = s.to_json();
        assert_eq!(j.path(&["e2e", "p50_us"]), Some(&Json::Null));
        // max_us goes through the same NaN→null guard as every other
        // moment — an empty track must not fabricate a zero maximum
        assert_eq!(j.path(&["e2e", "max_us"]), Some(&Json::Null));
        assert_eq!(j.path(&["queue", "max_us"]), Some(&Json::Null));
        assert_eq!(j.get("arrived").and_then(Json::as_f64), Some(0.0));
    }
}
