//! Open-loop arrival processes over a virtual microsecond clock.
//!
//! Three seed-deterministic processes generate request streams for the
//! batching-window server:
//!
//! * [`ArrivalProcess::Poisson`] — steady memoryless traffic at a fixed
//!   rate (the M/·/1 baseline every queueing result is stated against).
//! * [`ArrivalProcess::Bursty`] — a 2-state Markov-modulated Poisson
//!   process (MMPP-2): exponential dwell times alternate a calm rate and a
//!   burst rate, the canonical model for flash-crowd traffic.
//! * [`ArrivalProcess::Diurnal`] — a sinusoidally-modulated rate realized
//!   by Lewis–Shedler thinning against the peak rate, modelling the
//!   day/night cycle of a global user base.
//!
//! Inter-arrival gaps are **quantized to whole microseconds** (floor, min
//! 1 µs). That keeps every arrival timestamp an integer-valued `f64`, so
//! all downstream window/SLO arithmetic is exact IEEE-754 and the Python
//! transliteration in `python/tools/serving_reference.py` reproduces the
//! Rust server bit-for-bit: the only transcendental math (`ln`, `sin`)
//! is quarantined here, and the golden-fixture generator asserts each
//! draw lands far from its floor/accept boundary before committing it.
//!
//! Uniform draws come from a [`UniformSource`]: the crate's
//! xoshiro256**-backed [`Rng`] in production, or a recorded stream when
//! replaying the golden fixture.

use crate::prop::seed_from_env;
use crate::rng::Rng;

/// The serving suites' seed hook: `ARRIVAL_SEED` wins over the test's
/// default, and the value used is printed so a failing CI run names the
/// seed that reproduces it (libtest surfaces the print exactly when the
/// test fails).
pub fn arrival_seed(default: u64) -> u64 {
    let seed = seed_from_env("ARRIVAL_SEED", default);
    eprintln!("replay with: ARRIVAL_SEED={seed}");
    seed
}

/// One decode request emitted by an arrival process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Monotone request id (also the tie-free FIFO order).
    pub id: u64,
    /// Arrival timestamp on the virtual clock, µs (integer-valued).
    pub arrival_us: f64,
    /// Decode tokens the request contributes to its window's micro-batch.
    pub tokens: u64,
}

/// Open-loop arrival process shapes (rates in requests per second).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate memoryless arrivals.
    Poisson {
        /// Mean arrival rate, requests/s.
        rate_hz: f64,
    },
    /// 2-state MMPP: calm and burst phases with exponential dwell times.
    Bursty {
        /// Arrival rate inside calm phases, requests/s.
        calm_hz: f64,
        /// Arrival rate inside burst phases, requests/s.
        burst_hz: f64,
        /// Mean calm-phase dwell, µs.
        mean_calm_us: f64,
        /// Mean burst-phase dwell, µs.
        mean_burst_us: f64,
    },
    /// Sinusoidally-modulated rate `base_hz * (1 + amplitude * sin(2πt/period))`,
    /// realized by thinning against the peak rate.
    Diurnal {
        /// Mean arrival rate, requests/s.
        base_hz: f64,
        /// Relative modulation depth in [0, 1].
        amplitude: f64,
        /// Cycle length, µs.
        period_us: f64,
    },
}

/// How many decode tokens each request carries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TokenModel {
    /// Every request carries the same token count.
    Fixed(u64),
    /// Token counts ramp with the request id — request `i` carries
    /// `base + step * (i / every)` tokens, modelling drifting decode
    /// pressure (the golden fixture's "drift" regime).
    Ramp {
        /// Tokens carried by the first `every` requests.
        base: u64,
        /// Increment applied every `every` requests.
        step: u64,
        /// Requests per ramp step (must be > 0).
        every: u64,
    },
}

impl TokenModel {
    /// Tokens carried by request `id`.
    pub fn tokens(&self, id: u64) -> u64 {
        match *self {
            TokenModel::Fixed(t) => t,
            TokenModel::Ramp { base, step, every } => base + step * (id / every),
        }
    }
}

/// Where an [`ArrivalGen`]'s uniform draws come from.
#[derive(Clone, Debug)]
pub enum UniformSource {
    /// Seeded production source (xoshiro256** via [`Rng::f64`]).
    Seeded(Rng),
    /// Replays a recorded stream — the golden-fixture path. Panics if the
    /// stream runs dry (the fixture records exactly the draws consumed).
    Replay {
        /// Recorded uniforms in [0, 1), in consumption order.
        vals: Vec<f64>,
        /// Next index to consume.
        next: usize,
    },
}

impl UniformSource {
    fn draw(&mut self) -> f64 {
        match self {
            UniformSource::Seeded(rng) => rng.f64(),
            UniformSource::Replay { vals, next } => {
                let v = *vals.get(*next).expect("replay uniform stream exhausted");
                *next += 1;
                v
            }
        }
    }
}

/// Seed-deterministic request generator: an [`ArrivalProcess`] plus a
/// [`TokenModel`] driven by a [`UniformSource`] over a virtual clock.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    tokens: TokenModel,
    source: UniformSource,
    clock_us: f64,
    next_id: u64,
    /// MMPP state: currently in the burst phase?
    burst: bool,
    /// MMPP: virtual time the current phase ends, µs.
    phase_end_us: f64,
    /// Uniform draws consumed so far (pinned by the golden fixture).
    consumed: u64,
}

/// Exponential gap with the given rate (per second), quantized to whole
/// microseconds with a 1 µs floor. `u` is a uniform in [0, 1).
fn exp_gap_us(u: f64, rate_hz: f64) -> f64 {
    let x = -(1.0 - u).ln() / rate_hz * 1e6;
    x.floor().max(1.0)
}

/// Exponential dwell with the given mean (µs), quantized like the gaps.
fn exp_dwell_us(u: f64, mean_us: f64) -> f64 {
    let x = -(1.0 - u).ln() * mean_us;
    x.floor().max(1.0)
}

impl ArrivalGen {
    fn validate(process: &ArrivalProcess) {
        match *process {
            ArrivalProcess::Poisson { rate_hz } => assert!(rate_hz > 0.0, "rate must be positive"),
            ArrivalProcess::Bursty { calm_hz, burst_hz, mean_calm_us, mean_burst_us } => {
                assert!(calm_hz > 0.0 && burst_hz > 0.0, "rates must be positive");
                assert!(mean_calm_us >= 1.0 && mean_burst_us >= 1.0, "dwells must be >= 1 us");
            }
            ArrivalProcess::Diurnal { base_hz, amplitude, period_us } => {
                assert!(base_hz > 0.0, "rate must be positive");
                assert!((0.0..=1.0).contains(&amplitude), "amplitude must be in [0, 1]");
                assert!(period_us > 0.0, "period must be positive");
            }
        }
    }

    fn with_source(process: ArrivalProcess, tokens: TokenModel, mut source: UniformSource) -> Self {
        Self::validate(&process);
        if let TokenModel::Ramp { every, .. } = tokens {
            assert!(every > 0, "ramp step length must be > 0");
        }
        let mut consumed = 0u64;
        // MMPP starts calm; its first dwell is drawn at construction so
        // the draw order is fixed (and mirrored by the Python reference).
        let phase_end_us = if let ArrivalProcess::Bursty { mean_calm_us, .. } = process {
            consumed += 1;
            exp_dwell_us(source.draw(), mean_calm_us)
        } else {
            f64::INFINITY
        };
        ArrivalGen {
            process,
            tokens,
            source,
            clock_us: 0.0,
            next_id: 0,
            burst: false,
            phase_end_us,
            consumed,
        }
    }

    /// Production generator: uniforms from a fresh [`Rng`] seeded `seed`.
    pub fn new(process: ArrivalProcess, tokens: TokenModel, seed: u64) -> Self {
        Self::with_source(process, tokens, UniformSource::Seeded(Rng::new(seed)))
    }

    /// Replay generator: uniforms from a recorded stream (golden fixtures).
    pub fn with_uniforms(process: ArrivalProcess, tokens: TokenModel, vals: Vec<f64>) -> Self {
        Self::with_source(process, tokens, UniformSource::Replay { vals, next: 0 })
    }

    fn draw(&mut self) -> f64 {
        self.consumed += 1;
        self.source.draw()
    }

    /// Uniform draws consumed so far.
    pub fn uniforms_consumed(&self) -> u64 {
        self.consumed
    }

    /// Generate the next request (arrival times are non-decreasing and
    /// strictly increase by at least 1 µs between consecutive requests of
    /// the Poisson and bursty processes).
    pub fn next_request(&mut self) -> Request {
        match self.process {
            ArrivalProcess::Poisson { rate_hz } => {
                let u = self.draw();
                self.clock_us += exp_gap_us(u, rate_hz);
            }
            ArrivalProcess::Bursty { calm_hz, burst_hz, mean_calm_us, mean_burst_us } => loop {
                let rate = if self.burst { burst_hz } else { calm_hz };
                let u = self.draw();
                let candidate = self.clock_us + exp_gap_us(u, rate);
                if candidate <= self.phase_end_us {
                    self.clock_us = candidate;
                    break;
                }
                // phase flips before the candidate lands: jump to the
                // boundary, toggle, draw the new dwell, and (by
                // memorylessness) re-draw the gap in the new phase
                self.clock_us = self.phase_end_us;
                self.burst = !self.burst;
                let mean = if self.burst { mean_burst_us } else { mean_calm_us };
                let u2 = self.draw();
                self.phase_end_us = self.clock_us + exp_dwell_us(u2, mean);
            },
            ArrivalProcess::Diurnal { base_hz, amplitude, period_us } => {
                let peak_hz = base_hz * (1.0 + amplitude);
                loop {
                    let u = self.draw();
                    self.clock_us += exp_gap_us(u, peak_hz);
                    let phase = std::f64::consts::TAU * self.clock_us / period_us;
                    let accept = base_hz * (1.0 + amplitude * phase.sin()) / peak_hz;
                    let u2 = self.draw();
                    if u2 < accept {
                        break;
                    }
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        Request { id, arrival_us: self.clock_us, tokens: self.tokens.tokens(id) }
    }

    /// Generate the next `n` requests in arrival order.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut gen = ArrivalGen::new(
            ArrivalProcess::Poisson { rate_hz: 10_000.0 },
            TokenModel::Fixed(8),
            42,
        );
        let reqs = gen.take(5_000);
        let span_s = reqs.last().unwrap().arrival_us / 1e6;
        let rate = reqs.len() as f64 / span_s;
        assert!((rate - 10_000.0).abs() / 10_000.0 < 0.1, "empirical rate {rate}");
        assert!(reqs.windows(2).all(|w| w[0].arrival_us < w[1].arrival_us));
        assert!(reqs.iter().all(|r| r.arrival_us == r.arrival_us.floor()), "integer µs");
    }

    #[test]
    fn bursty_mixes_two_rates() {
        let mut gen = ArrivalGen::new(
            ArrivalProcess::Bursty {
                calm_hz: 1_000.0,
                burst_hz: 50_000.0,
                mean_calm_us: 20_000.0,
                mean_burst_us: 20_000.0,
            },
            TokenModel::Fixed(8),
            7,
        );
        let reqs = gen.take(5_000);
        let span_s = reqs.last().unwrap().arrival_us / 1e6;
        let rate = reqs.len() as f64 / span_s;
        // empirical rate must land strictly between the two phase rates
        assert!(rate > 1_500.0 && rate < 49_000.0, "empirical rate {rate}");
        assert!(reqs.windows(2).all(|w| w[0].arrival_us < w[1].arrival_us));
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let period = 1_000_000.0;
        let mut gen = ArrivalGen::new(
            ArrivalProcess::Diurnal { base_hz: 20_000.0, amplitude: 0.875, period_us: period },
            TokenModel::Fixed(8),
            3,
        );
        let reqs = gen.take(40_000);
        // count arrivals in the rising half vs the falling half of cycle 0
        let (mut peak, mut trough) = (0u64, 0u64);
        for r in &reqs {
            let phase = (r.arrival_us % period) / period;
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "sin-modulated halves should differ: peak {peak} trough {trough}"
        );
    }

    #[test]
    fn token_ramp_steps() {
        let m = TokenModel::Ramp { base: 8, step: 4, every: 10 };
        assert_eq!(m.tokens(0), 8);
        assert_eq!(m.tokens(9), 8);
        assert_eq!(m.tokens(10), 12);
        assert_eq!(m.tokens(25), 16);
    }

    #[test]
    fn identical_seed_identical_stream() {
        let p = ArrivalProcess::Bursty {
            calm_hz: 2_000.0,
            burst_hz: 20_000.0,
            mean_calm_us: 10_000.0,
            mean_burst_us: 5_000.0,
        };
        let a = ArrivalGen::new(p, TokenModel::Fixed(16), 99).take(500);
        let b = ArrivalGen::new(p, TokenModel::Fixed(16), 99).take(500);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn replay_source_panics_when_dry() {
        let mut gen = ArrivalGen::with_uniforms(
            ArrivalProcess::Poisson { rate_hz: 1_000.0 },
            TokenModel::Fixed(1),
            vec![0.5],
        );
        gen.next_request();
        gen.next_request();
    }
}
