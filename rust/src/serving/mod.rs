//! Online serving tier: open-loop request streams over the balancing
//! stack (ARCHITECTURE.md §9).
//!
//! Training drives the schedulers step-by-step; serving is the other
//! regime the ROADMAP's north star demands — continuous request streams
//! whose arrival process, not a training loop, decides when work exists.
//! This module stacks three layers on top of the [`crate::balancer`]
//! facade:
//!
//! * [`arrivals`] — seed-deterministic Poisson / bursty-MMPP / diurnal
//!   arrival processes over a virtual microsecond clock ([`ArrivalGen`]),
//!   emitting [`Request`]s whose decode-token counts follow a
//!   [`TokenModel`].
//! * [`server`] — the open-loop batching-window loop ([`MoeServer`]):
//!   collect for `window_us` or `max_batch`, shed stale requests, scatter
//!   the survivors over a drifting [`crate::workload::TopicMix`], drive
//!   any registered policy, and charge solve + dispatch latency; plus the
//!   closed-loop [`ServingRunner`] benches use instead of hand-rolled
//!   step loops.
//! * [`sla`] — per-request queue/solve/dispatch/e2e latency accounting
//!   with exact and P² streaming percentiles, deadline-miss and shed
//!   counters ([`SlaStats`]).
//!
//! Determinism contract: with [`SolveCost::Virtual`] the entire run —
//! request trace, per-window plans, and [`SlaStats`] — is a pure function
//! of `(process, token model, seed, config)`, bit-identical across runs
//! and engine worker counts, and transliterated op-for-op by
//! `python/tools/serving_reference.py` into the golden-serving fixture.

pub mod arrivals;
pub mod server;
pub mod sla;

pub use arrivals::{arrival_seed, ArrivalGen, ArrivalProcess, Request, TokenModel, UniformSource};
pub use server::{
    DispatchCost, MoeServer, ServingConfig, ServingRunner, ServingTrace, SolveCost, WindowRecord,
};
pub use sla::SlaStats;
