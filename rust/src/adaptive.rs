//! Adaptive replacement (§6.4): the long-term complement to per-micro-batch
//! token scheduling.
//!
//! The placement manager monitors per-micro-batch expert loads, predicts the
//! near-future distribution with a windowed moving average (the paper cites
//! time-series techniques; moving averages are its named example), evaluates
//! the *current* placement on the prediction via Eq. 3 (max induced subgraph
//! density — no LP solve needed), and triggers a new asymmetric placement
//! when predicted balance degrades past a threshold.

use crate::placement::asymmetric::asymmetric_placement;
use crate::placement::graph::{max_induced_density, perfect_balance_bound};
use crate::placement::Placement;
use crate::rng::Rng;
use crate::stats::VecWindow;

/// Tuning knobs for the placement manager.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// moving-average window (micro-batches)
    pub window: usize,
    /// evaluate the trigger every this many micro-batches
    pub check_every: usize,
    /// replace when predicted density exceeds `threshold ×` perfect balance
    pub threshold: f64,
    /// Monte-Carlo samples for the new placement search
    pub mc_samples: usize,
    /// replica slots per GPU the new placement may use
    pub slots_per_gpu: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            window: 16,
            check_every: 8,
            threshold: 1.05,
            mc_samples: 64,
            slots_per_gpu: 4,
        }
    }
}

/// Outcome of a replacement decision.
#[derive(Clone, Debug)]
pub struct ReplacementDecision {
    /// The placement to switch to.
    pub placement: Placement,
    /// predicted density of the *old* placement that triggered this
    pub old_density: f64,
    /// density of the new placement on the same prediction
    pub new_density: f64,
}

/// The placement manager (Fig. 4, device-0 resident in MicroMoE; here a
/// plain struct the coordinator owns).
pub struct ReplacementManager {
    cfg: AdaptiveConfig,
    history: VecWindow,
    batch: usize,
    rng: Rng,
    /// number of replacements performed (exposed for tests/metrics)
    pub replacements: usize,
}

impl ReplacementManager {
    /// Manager over a fresh history window.
    pub fn new(cfg: AdaptiveConfig, seed: u64) -> Self {
        let window = cfg.window;
        ReplacementManager {
            cfg,
            history: VecWindow::new(window),
            batch: 0,
            rng: Rng::new(seed),
            replacements: 0,
        }
    }

    /// Record one micro-batch's expert loads.
    pub fn observe(&mut self, expert_loads: &[u64]) {
        self.history
            .push(expert_loads.iter().map(|&l| l as f64).collect());
        self.batch += 1;
    }

    /// Predicted near-future expert loads (windowed moving average).
    pub fn predict(&self) -> Option<Vec<f64>> {
        self.history.mean()
    }

    /// Check the trigger; return a new placement when warranted.
    pub fn maybe_replace(&mut self, current: &Placement) -> Option<ReplacementDecision> {
        if self.batch == 0 || self.batch % self.cfg.check_every != 0 {
            return None;
        }
        if self.history.len() < self.cfg.window.min(4) {
            return None; // not enough signal yet
        }
        let predicted = self.predict()?;
        let ideal = perfect_balance_bound(&predicted, current.num_gpus);
        if ideal <= 0.0 {
            return None;
        }
        let old_density = max_induced_density(current, &predicted, &mut self.rng).density;
        if old_density <= self.cfg.threshold * ideal {
            return None; // current placement still schedulable to balance
        }
        let candidate = asymmetric_placement(
            current.num_gpus,
            &predicted,
            self.cfg.slots_per_gpu,
            self.cfg.mc_samples,
            &mut self.rng,
        );
        let new_density = max_induced_density(&candidate, &predicted, &mut self.rng).density;
        if new_density >= old_density * 0.999 {
            return None; // no improvement worth a migration
        }
        self.replacements += 1;
        Some(ReplacementDecision { placement: candidate, old_density, new_density })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Zipf;

    fn skewed_loads(rng: &mut Rng, experts: usize, s: f64, tokens: u64) -> Vec<u64> {
        let z = Zipf::new(experts, s);
        let mut loads = vec![0u64; experts];
        for _ in 0..tokens {
            loads[z.sample(rng)] += 1;
        }
        loads
    }

    #[test]
    fn no_replacement_on_balanced_loads() {
        let p = cayley_graph_placement(8, 16);
        let mut mgr = ReplacementManager::new(AdaptiveConfig::default(), 1);
        let mut rng = Rng::new(2);
        for _ in 0..64 {
            mgr.observe(&skewed_loads(&mut rng, 16, 0.0, 2000));
            assert!(
                mgr.maybe_replace(&p).is_none(),
                "replaced under uniform loads"
            );
        }
        assert_eq!(mgr.replacements, 0);
    }

    #[test]
    fn replaces_under_heavy_skew() {
        let p = cayley_graph_placement(8, 16); // uniform 2 replicas each
        let mut mgr = ReplacementManager::new(
            AdaptiveConfig { slots_per_gpu: 4, ..Default::default() },
            1,
        );
        let mut rng = Rng::new(3);
        let mut decided = None;
        for _ in 0..64 {
            mgr.observe(&skewed_loads(&mut rng, 16, 1.8, 4000));
            if let Some(d) = mgr.maybe_replace(&p) {
                decided = Some(d);
                break;
            }
        }
        let d = decided.expect("never replaced under s=1.8 skew");
        assert!(d.new_density < d.old_density);
        d.placement.check_consistency().unwrap();
    }

    #[test]
    fn replacement_improves_eq3_density() {
        let p = cayley_graph_placement(4, 8);
        let mut mgr = ReplacementManager::new(
            AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 4, ..Default::default() },
            9,
        );
        let mut rng = Rng::new(4);
        for _ in 0..32 {
            mgr.observe(&skewed_loads(&mut rng, 8, 2.0, 3000));
            if let Some(d) = mgr.maybe_replace(&p) {
                assert!(d.new_density <= d.old_density);
                return;
            }
        }
        panic!("trigger never fired");
    }

    #[test]
    fn respects_check_period() {
        let p = cayley_graph_placement(4, 8);
        let mut mgr = ReplacementManager::new(
            AdaptiveConfig { check_every: 100, ..Default::default() },
            5,
        );
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            mgr.observe(&skewed_loads(&mut rng, 8, 2.0, 1000));
            assert!(mgr.maybe_replace(&p).is_none());
        }
    }

    #[test]
    fn prediction_is_window_mean() {
        let mut mgr = ReplacementManager::new(
            AdaptiveConfig { window: 2, ..Default::default() },
            7,
        );
        mgr.observe(&[10, 0]);
        mgr.observe(&[0, 10]);
        assert_eq!(mgr.predict().unwrap(), vec![5.0, 5.0]);
    }
}
