//! Deterministic fault injection — the chaos-test harness behind
//! `tests/chaos.rs` and ISSUE-6's robustness acceptance criteria.
//!
//! A [`FaultPlan`] is a finite list of `(step, layer) → Fault` injections,
//! derived deterministically from a seed so any CI failure replays exactly
//! (`FAULT_SEED=<seed> cargo test --test chaos`, mirroring the
//! `LP_FUZZ_SEED` convention of the LP fuzz suites). The plan is threaded
//! through [`crate::scheduler::SchedulerOptions::faults`]; with the default
//! `None` nothing is consulted and every path is bit-identical to a
//! fault-free build.
//!
//! # Fault model
//!
//! | fault | injected where | expected degradation |
//! |---|---|---|
//! | [`Fault::WorkerPanic`] | engine worker thread, before the solve | worker respawn + cold re-solve (or passthrough past the respawn limit) |
//! | [`Fault::BudgetStarvation`] | zero-pivot [`crate::lp::SolveBudget`] for this solve | greedy rung, `budget_pivots` count |
//! | [`Fault::NanLoads`] | `NaN` into the LP rhs updates | input validation rejects, greedy rung |
//! | [`Fault::OverflowLoads`] | `~1e300` into the LP rhs updates | input validation rejects, greedy rung |
//! | [`Fault::ForceInfeasible`] | `−1` rhs on an equality row | LP reports `Infeasible`, greedy rung |
//!
//! Every fault degrades the plan, never the *feasibility* of the emitted
//! schedule: the load perturbations poison only the LP's view, while the
//! greedy fallback and token routing work from the true integer loads.

use crate::rng::Rng;

/// One injectable fault (see the module-level fault model table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fault {
    /// Kill the engine worker thread that owns this `(step, layer)` commit.
    /// `persistent` re-arms after every respawn (drives the respawn limit
    /// and the passthrough rung); one-shot panics fire exactly once.
    WorkerPanic {
        /// Whether the panic re-fires on the respawned worker too.
        persistent: bool,
    },
    /// Run this solve under a zero-pivot budget: both LP rungs exhaust
    /// immediately and the ladder lands on greedy.
    BudgetStarvation,
    /// Poison one LP rhs update with `NaN`.
    NanLoads,
    /// Poison one LP rhs update with a value far beyond the exactly-
    /// representable integer range (`~1e300`).
    OverflowLoads,
    /// Rewrite one expert's conservation row to an unsatisfiable `= −1`.
    ForceInfeasible,
}

impl Fault {
    /// Whether the fault is handled by the engine worker (vs the
    /// scheduler's solve path).
    pub fn is_worker_fault(&self) -> bool {
        matches!(self, Fault::WorkerPanic { .. })
    }
}

/// A deterministic `(step, layer) → Fault` injection schedule, at most one
/// fault per slot.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    /// Sorted, deduplicated `(step, layer, fault)` triples.
    faults: Vec<(usize, usize, Fault)>,
}

impl FaultPlan {
    /// No faults at all.
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    /// Derive a plan from a seed: each `(step, layer)` slot independently
    /// receives a fault with probability `density`, the kind drawn
    /// uniformly from the non-persistent kinds. Fully determined by
    /// `(seed, steps, layers, density)`.
    pub fn from_seed(seed: u64, steps: usize, layers: usize, density: f64) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA01_7D5A_11CE_0BAD);
        let kinds = [
            Fault::WorkerPanic { persistent: false },
            Fault::BudgetStarvation,
            Fault::NanLoads,
            Fault::OverflowLoads,
            Fault::ForceInfeasible,
        ];
        let mut faults = Vec::new();
        for step in 0..steps {
            for layer in 0..layers {
                if rng.f64() < density {
                    let kind = kinds[rng.below(kinds.len() as u64) as usize];
                    faults.push((step, layer, kind));
                }
            }
        }
        FaultPlan { seed, faults }
    }

    /// Build an explicit plan (targeted tests). Triples are sorted and
    /// later duplicates for the same `(step, layer)` are dropped.
    pub fn with_faults(mut faults: Vec<(usize, usize, Fault)>) -> Self {
        faults.sort_by_key(|&(s, l, _)| (s, l));
        faults.dedup_by_key(|&mut (s, l, _)| (s, l));
        FaultPlan { seed: 0, faults }
    }

    /// The fault injected at `(step, layer)`, if any.
    pub fn at(&self, step: usize, layer: usize) -> Option<Fault> {
        self.faults
            .binary_search_by_key(&(step, layer), |&(s, l, _)| (s, l))
            .ok()
            .map(|i| self.faults[i].2)
    }

    /// All injections, sorted by `(step, layer)`.
    pub fn faults(&self) -> &[(usize, usize, Fault)] {
        &self.faults
    }

    /// The seed this plan was derived from (0 for explicit plans).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Whether the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// The chaos suite's seed hook: `FAULT_SEED` wins over the test's default,
/// and the value used is printed so a failing CI run names the seed that
/// reproduces it (libtest surfaces the print exactly when the test fails).
pub fn fault_seed(default: u64) -> u64 {
    let seed = crate::prop::seed_from_env("FAULT_SEED", default);
    eprintln!("replay with: FAULT_SEED={seed}");
    seed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seed_is_deterministic() {
        let a = FaultPlan::from_seed(42, 20, 4, 0.3);
        let b = FaultPlan::from_seed(42, 20, 4, 0.3);
        assert_eq!(a, b);
        assert_eq!(a.seed(), 42);
        let c = FaultPlan::from_seed(43, 20, 4, 0.3);
        assert_ne!(a.faults(), c.faults(), "different seeds, different plans");
    }

    #[test]
    fn density_scales_fault_count() {
        assert!(FaultPlan::from_seed(1, 50, 4, 0.0).is_empty());
        let full = FaultPlan::from_seed(1, 50, 4, 1.0);
        assert_eq!(full.faults().len(), 200, "density 1.0 hits every slot");
        let some = FaultPlan::from_seed(1, 50, 4, 0.25);
        assert!(!some.is_empty() && some.faults().len() < 200);
    }

    #[test]
    fn at_looks_up_injections() {
        let plan = FaultPlan::with_faults(vec![
            (3, 1, Fault::NanLoads),
            (0, 0, Fault::BudgetStarvation),
            (3, 1, Fault::OverflowLoads), // duplicate slot: dropped
        ]);
        assert_eq!(plan.at(0, 0), Some(Fault::BudgetStarvation));
        assert_eq!(plan.at(3, 1), Some(Fault::NanLoads));
        assert_eq!(plan.at(1, 1), None);
        assert_eq!(plan.faults().len(), 2);
    }

    #[test]
    fn worker_faults_classified() {
        assert!(Fault::WorkerPanic { persistent: true }.is_worker_fault());
        assert!(!Fault::BudgetStarvation.is_worker_fault());
        assert!(!Fault::ForceInfeasible.is_worker_fault());
    }
}
