//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — the HLO text is the entire interface.
//! HLO *text* (not serialized proto) is mandatory with this image's
//! xla_extension 0.5.1 (jax ≥0.5 emits 64-bit instruction ids the proto
//! path rejects; the text parser reassigns them).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::ser::Json;

/// Parsed `manifest.json`: artifact I/O specs plus the model config.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub num_params: usize,
    pub capacity: usize,
    /// model config fields (vocab, seq, hidden, layers, experts, topk, …)
    pub config: HashMap<String, f64>,
    pub artifacts: Vec<ArtifactSpec>,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
        })
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut config = HashMap::new();
        if let Some(Json::Obj(cfg)) = j.get("config") {
            for (k, v) in cfg {
                if let Some(x) = v.as_f64() {
                    config.insert(k.clone(), x);
                }
            }
        }
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    inputs: a
                        .get("inputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            preset: j.get("preset").and_then(Json::as_str).unwrap_or("?").to_string(),
            num_params: j.get("num_params").and_then(Json::as_usize).unwrap_or(0),
            capacity: j.get("capacity").and_then(Json::as_usize).unwrap_or(0),
            config,
            artifacts,
        })
    }

    pub fn cfg(&self, key: &str) -> Option<f64> {
        self.config.get(key).copied()
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// The PJRT runtime: one CPU client, lazily compiled executables.
pub struct Runtime {
    dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifacts directory (env `MICROMOE_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MICROMOE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest, client, exes: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.compile(name)?;
        Ok(&self.exes[name])
    }

    /// Execute with literal inputs; returns one literal per declared output
    /// (tuple-wrapped results are decomposed).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let n_out = self
            .manifest
            .artifact(name)
            .map(|a| a.outputs.len())
            .unwrap_or(1);
        let exe = self.exe(name)?;
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let bufs = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{name}: no replica output"))?;
        let mut lits = Vec::with_capacity(bufs.len());
        for b in bufs {
            lits.push(b.to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?);
        }
        // AOT lowers with return_tuple=True: one buffer holding an n-tuple
        if lits.len() == 1 && n_out > 1 {
            let only = lits.pop().unwrap();
            let parts = only.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            if parts.len() != n_out {
                bail!("{name}: {} tuple elements, manifest says {n_out}", parts.len());
            }
            return Ok(parts);
        }
        if lits.len() == 1 && n_out == 1 {
            // may still be a 1-tuple
            let only = lits.pop().unwrap();
            return match only.shape() {
                Ok(xla::Shape::Tuple(_)) => {
                    Ok(only.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?)
                }
                _ => Ok(vec![only]),
            };
        }
        Ok(lits)
    }

    /// f32 helper: run and pull each output as Vec<f32>.
    pub fn execute_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.execute(name, inputs)?
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Literal constructors for the shapes this system moves around.
pub mod lit {
    use anyhow::{anyhow, Result};

    pub fn f32_vec(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    pub fn f32_matrix(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn f32_tensor3(data: &[f32], d0: usize, d1: usize, d2: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), d0 * d1 * d2);
        xla::Literal::vec1(data)
            .reshape(&[d0 as i64, d1 as i64, d2 as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn i32_matrix(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn f32_scalar(x: f32) -> xla::Literal {
        xla::Literal::scalar(x)
    }

    pub fn i32_scalar(x: i32) -> xla::Literal {
        xla::Literal::scalar(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_spec_fields() {
        let text = r#"{
          "preset": "smoke", "num_params": 123, "capacity": 8,
          "config": {"hidden": 32, "experts": 4, "use_pallas": true},
          "artifacts": [
            {"name": "gate", "file": "gate.hlo.txt",
             "inputs": [{"name": "logits", "shape": [64, 4], "dtype": "float32"}],
             "outputs": [{"name": "w", "shape": [64, 2], "dtype": "float32"},
                          {"name": "i", "shape": [64, 2], "dtype": "int32"}]}
          ]
        }"#;
        let dir = std::env::temp_dir().join(format!("mm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "smoke");
        assert_eq!(m.num_params, 123);
        assert_eq!(m.cfg("hidden"), Some(32.0));
        let a = m.artifact("gate").unwrap();
        assert_eq!(a.inputs[0].shape, vec![64, 4]);
        assert_eq!(a.outputs[1].dtype, "int32");
        assert_eq!(a.inputs[0].element_count(), 256);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
