//! End-to-end training driver: the real-numerics path that proves the three
//! layers compose (Pallas kernel ∘ JAX train step ∘ AOT ∘ PJRT ∘ MicroEP).
//!
//! The AOT `train_step` artifact advances (params, m, v, step) with Adam on
//! one micro-batch and reports the loss plus per-layer per-expert gate
//! counts. The driver treats consecutive micro-batches as the micro-batches
//! of `dp_virtual` data-parallel ranks, assembles real `input_e^g` matrices
//! from the gate counts, and runs MicroEP scheduling on them — producing
//! the Fig.-2-style trace and real-load balance numbers recorded in
//! EXPERIMENTS.md.

use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::balancer::{Balancer, MoeSession};
use crate::engine::EngineMode;
use crate::placement::cayley::symmetric_placement;
use crate::rng::Rng;
use crate::runtime::{lit, Runtime};
use crate::scheduler::LoadMatrix;
use crate::stats::imbalance_ratio;
use crate::topology::Topology;
use crate::workload::TraceWorkload;

/// Synthetic corpus: a fixed pool of random sequences (the model memorizes
/// the pool, so the loss curve must descend — the e2e success criterion).
pub struct Corpus {
    pool: Vec<Vec<i32>>,
    rng: Rng,
}

impl Corpus {
    pub fn new(vocab: usize, seq_plus_1: usize, pool_size: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // Markov-flavored sequences: structured transitions + noise, so
        // there is signal beyond memorization too.
        let pool = (0..pool_size)
            .map(|_| {
                let mut s = Vec::with_capacity(seq_plus_1);
                let mut cur = rng.below(vocab as u64) as i64;
                let stride = 1 + rng.below(7) as i64;
                for _ in 0..seq_plus_1 {
                    s.push(cur as i32);
                    cur = if rng.f64() < 0.9 {
                        (cur + stride) % vocab as i64
                    } else {
                        rng.below(vocab as u64) as i64
                    };
                }
                s
            })
            .collect();
        Corpus { pool, rng }
    }

    /// One micro-batch: `batch` sequences of length `seq+1`, flattened.
    pub fn batch(&mut self, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.pool[0].len());
        for _ in 0..batch {
            let i = self.rng.below(self.pool.len() as u64) as usize;
            out.extend_from_slice(&self.pool[i]);
        }
        out
    }
}

/// One training step's observables.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub loss: f32,
    /// per-layer per-expert gate counts (layers × experts)
    pub counts: Vec<Vec<u64>>,
}

/// Full run log (feeds EXPERIMENTS.md and the Fig-2 trace).
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    /// per-DP-round max/avg imbalance: (vanilla EP, MicroEP)
    pub imbalance: Vec<(f64, f64)>,
    /// layer-0 load matrices per DP round (the Fig-2 trace)
    pub trace: Vec<LoadMatrix>,
    pub step_seconds: Vec<f64>,
}

pub struct Trainer {
    rt: Runtime,
    pub vocab: usize,
    pub seq: usize,
    pub micro_batch: usize,
    pub layers: usize,
    pub experts: usize,
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    step_ctr: xla::Literal,
    corpus: Corpus,
    pub dp_virtual: usize,
    /// How the per-DP-round multi-layer scheduling executes through the
    /// session facade: pipelined engine by default; `--engine speculative`
    /// adds forecast-driven pre-solves between rounds, `--engine barrier`
    /// keeps the round-barrier fan-out for ablation.
    pub engine_mode: EngineMode,
    /// Span tracer threaded into the scheduling session (off — zero-cost —
    /// by default; `micromoe train --trace <path>` installs a Wall-clock
    /// tracer and exports the recorded spans as Chrome-trace JSON).
    pub tracer: crate::obs::Tracer,
}

impl Trainer {
    pub fn new(mut rt: Runtime, seed: u64) -> Result<Self> {
        let cfg = |k: &str| -> Result<usize> {
            rt.manifest
                .cfg(k)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest missing config.{k}"))
        };
        let vocab = cfg("vocab")?;
        let seq = cfg("seq")?;
        let micro_batch = cfg("micro_batch")?;
        let layers = cfg("layers")?;
        let experts = cfg("experts")?;
        let p = rt.manifest.num_params;

        log::info!("initializing {p} params (preset {})", rt.manifest.preset);
        let outs = rt
            .execute("init_params", &[lit::i32_scalar(seed as i32)])
            .context("init_params")?;
        let params = outs.into_iter().next().ok_or_else(|| anyhow!("no params output"))?;
        let zeros = vec![0f32; p];
        let corpus = Corpus::new(vocab, seq + 1, 64, seed ^ 0xBEEF);
        Ok(Trainer {
            rt,
            vocab,
            seq,
            micro_batch,
            layers,
            experts,
            params,
            m: lit::f32_vec(&zeros),
            v: lit::f32_vec(&zeros),
            step_ctr: lit::f32_scalar(0.0),
            corpus,
            dp_virtual: 8,
            engine_mode: EngineMode::pipeline(),
            tracer: crate::obs::Tracer::off(),
        })
    }

    /// One optimizer step on one micro-batch.
    pub fn step(&mut self) -> Result<StepResult> {
        let tokens = self.corpus.batch(self.micro_batch);
        let tok_lit = lit::i32_matrix(&tokens, self.micro_batch, self.seq + 1)?;
        let outs = self.rt.execute(
            "train_step",
            &[
                std::mem::replace(&mut self.params, lit::f32_scalar(0.0)),
                std::mem::replace(&mut self.m, lit::f32_scalar(0.0)),
                std::mem::replace(&mut self.v, lit::f32_scalar(0.0)),
                std::mem::replace(&mut self.step_ctr, lit::f32_scalar(0.0)),
                tok_lit,
            ],
        )?;
        let mut it = outs.into_iter();
        self.params = it.next().ok_or_else(|| anyhow!("missing params'"))?;
        self.m = it.next().ok_or_else(|| anyhow!("missing m'"))?;
        self.v = it.next().ok_or_else(|| anyhow!("missing v'"))?;
        self.step_ctr = it.next().ok_or_else(|| anyhow!("missing step'"))?;
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let counts_raw = it
            .next()
            .ok_or_else(|| anyhow!("missing counts"))?
            .to_vec::<i32>()
            .map_err(|e| anyhow!("counts: {e:?}"))?;
        let counts = counts_raw
            .chunks(self.experts)
            .map(|c| c.iter().map(|&x| x as u64).collect())
            .collect();
        Ok(StepResult { loss, counts })
    }

    /// Train `steps` micro-batches; every `dp_virtual` steps, assemble the
    /// real per-layer load matrices and schedule *all* MoE layers — each
    /// with its own warm-started scheduler — in parallel, comparing MicroEP
    /// against vanilla EP on the same loads.
    pub fn run(&mut self, steps: usize, log_every: usize) -> Result<TrainLog> {
        let topo = Topology::new(self.dp_virtual, (self.dp_virtual / 2).max(1), 2, 8);
        let placement = symmetric_placement(&topo, self.experts);
        // the unified facade owns one warm scheduler per MoE layer (the
        // gate distributions of different layers are unrelated) plus, for
        // the engine modes, the persistent worker pool and forecasters:
        // the pipelined engine emits each layer's plan while the remaining
        // layers still solve, and the speculative mode pre-solves the next
        // round's forecast between rounds — no per-round thread spawns
        let mut session = MoeSession::builder()
            .topology(topo.clone())
            .placement(placement)
            .engine(self.engine_mode)
            .tracer(self.tracer.clone())
            .layers(self.layers)
            .build()
            .map_err(|e| anyhow!("scheduling session: {e}"))?;
        let mut vanilla = crate::baselines::VanillaEp::new(topo.clone(), self.experts);

        let mut log_out = TrainLog::default();
        let mut rounds: Vec<LoadMatrix> =
            (0..self.layers).map(|_| LoadMatrix::zeros(self.experts, self.dp_virtual)).collect();
        for s in 0..steps {
            let t0 = std::time::Instant::now();
            let r = self.step()?;
            log_out.step_seconds.push(t0.elapsed().as_secs_f64());
            log_out.losses.push(r.loss);
            let g = s % self.dp_virtual;
            for (l, counts) in r.counts.iter().enumerate().take(self.layers) {
                for (e, &c) in counts.iter().enumerate() {
                    rounds[l].set(e, g, c);
                }
            }
            if g == self.dp_virtual - 1 {
                // schedule the completed DP round on real loads, all layers
                // at once (pipelined through the session's worker pool)
                let out = session.step(&rounds);
                let micro_imb = out
                    .layers
                    .iter()
                    .map(|p| {
                        imbalance_ratio(
                            &p.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                        )
                    })
                    .sum::<f64>()
                    / out.layers.len() as f64;
                // baseline over the same per-layer workloads, so the
                // (vanilla, MicroEP) pair measures identical loads
                let van_imb = rounds
                    .iter()
                    .map(|round| {
                        let plan = vanilla.plan(round);
                        imbalance_ratio(
                            &plan.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                        )
                    })
                    .sum::<f64>()
                    / rounds.len() as f64;
                log_out.imbalance.push((van_imb, micro_imb));
                log_out.trace.push(rounds[0].clone());
                for round in &mut rounds {
                    *round = LoadMatrix::zeros(self.experts, self.dp_virtual);
                }
            }
            if log_every > 0 && s % log_every == 0 {
                log::info!("step {s}: loss {:.4}", r.loss);
                println!("step {s:>5}  loss {:.4}", r.loss);
            }
        }
        Ok(log_out)
    }

    /// Persist the Fig-2 trace for replay by benches.
    pub fn save_trace(log: &TrainLog, path: &PathBuf) -> Result<()> {
        if log.trace.is_empty() {
            return Ok(());
        }
        let t = TraceWorkload::new(log.trace.clone());
        std::fs::write(path, t.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Measure the expert-FFN artifact at two capacities to calibrate the
    /// cluster cost model from real PJRT compute timings.
    pub fn calibrate(rt: &mut Runtime) -> Result<((u64, f64), (u64, f64))> {
        let mut measure = |name: &str| -> Result<(u64, f64)> {
            let spec = rt
                .manifest
                .artifact(name)
                .ok_or_else(|| anyhow!("missing {name}"))?
                .clone();
            let (e, c, h) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1], spec.inputs[0].shape[2]);
            let f = spec.inputs[1].shape[2];
            let x = lit::f32_tensor3(&vec![0.1; e * c * h], e, c, h)?;
            let w1 = lit::f32_tensor3(&vec![0.01; e * h * f], e, h, f)?;
            let w2 = lit::f32_tensor3(&vec![0.01; e * f * h], e, f, h)?;
            rt.execute(name, &[&x, &w1, &w2].map(|l| l.clone()))?; // warm
            let t0 = std::time::Instant::now();
            let reps = 3;
            for _ in 0..reps {
                rt.execute(name, &[&x, &w1, &w2].map(|l| l.clone()))?;
            }
            Ok(((e * c) as u64, t0.elapsed().as_secs_f64() / reps as f64))
        };
        let small = measure("expert_ffn_small")?;
        let large = measure("expert_ffn_large")?;
        Ok((small, large))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_batches_are_in_vocab() {
        let mut c = Corpus::new(64, 17, 8, 1);
        let b = c.batch(4);
        assert_eq!(b.len(), 4 * 17);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn corpus_is_deterministic_per_seed() {
        let mut a = Corpus::new(64, 17, 8, 5);
        let mut b = Corpus::new(64, 17, 8, 5);
        assert_eq!(a.batch(2), b.batch(2));
    }

    #[test]
    fn corpus_reuses_pool() {
        // with a tiny pool, repeated batches must repeat sequences
        let mut c = Corpus::new(32, 9, 2, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            for chunk in c.batch(1).chunks(9) {
                seen.insert(chunk.to_vec());
            }
        }
        assert!(seen.len() <= 2);
    }
}
