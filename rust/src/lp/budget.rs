//! Deterministic per-solve budgets — the robustness layer's contract with
//! the simplex engines.
//!
//! A production balancer cannot let one numerically nasty micro-batch hold
//! the training step hostage: the scheduler needs a *bounded* answer to
//! "how long may this solve run?" that is reproducible across machines.
//! [`SolveBudget`] expresses that bound in units the solver already counts
//! deterministically — pivots (basis changes + bound flips) and basis
//! refactorizations — plus an *optional* wall-clock cap for deployments
//! that prefer an SLO over determinism. The pivot/refactor caps are exact
//! and replayable: the same instance with the same budget exhausts at the
//! same pivot on every run. The wall-clock cap is best-effort and
//! explicitly non-deterministic; it is checked only when set, so the
//! default (unlimited) budget never reads the clock and stays bit-stable.
//!
//! Exhaustion surfaces as
//! [`SimplexError::BudgetExhausted`](super::simplex::SimplexError) carrying
//! a [`BudgetReason`], and callers that want a success-or-degrade view
//! instead of a `Result` can classify any solve through [`SolveOutcome`].

use super::simplex::{SimplexError, Solution};

/// Per-solve resource budget. `None` fields are unlimited; the default is
/// fully unlimited, which keeps every pre-existing path byte-identical
/// (no counter comparisons change behaviour, and the clock is never read).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolveBudget {
    /// Cap on pivots (basis changes plus bound flips, the same unit as
    /// [`super::SolveStats::pivots`]) spent by one solve attempt.
    pub max_pivots: Option<usize>,
    /// Cap on basis refactorizations within one solve attempt.
    pub max_refactors: Option<usize>,
    /// Optional wall-clock cap. **Non-deterministic**: two runs of the same
    /// instance may exhaust at different pivots. Checked only when set.
    pub max_wall: Option<std::time::Duration>,
}

impl SolveBudget {
    /// Fully unlimited budget (the default).
    pub fn unlimited() -> Self {
        Self::default()
    }

    /// Pivot-capped budget with everything else unlimited.
    pub fn with_max_pivots(max_pivots: usize) -> Self {
        SolveBudget { max_pivots: Some(max_pivots), ..Self::default() }
    }

    /// Whether no cap is set at all — the bit-stable fast path.
    pub fn is_unlimited(&self) -> bool {
        self.max_pivots.is_none() && self.max_refactors.is_none() && self.max_wall.is_none()
    }
}

/// Which budget dimension ran out first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BudgetReason {
    /// The pivot cap ([`SolveBudget::max_pivots`]) was reached.
    Pivots,
    /// The refactorization cap ([`SolveBudget::max_refactors`]) was reached.
    Refactors,
    /// The wall-clock deadline ([`SolveBudget::max_wall`]) passed.
    WallClock,
}

impl std::fmt::Display for BudgetReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetReason::Pivots => write!(f, "pivot cap"),
            BudgetReason::Refactors => write!(f, "refactorization cap"),
            BudgetReason::WallClock => write!(f, "wall-clock deadline"),
        }
    }
}

/// Typed outcome of a budgeted solve attempt — the success-or-degrade view
/// the degradation ladder consumes instead of matching on raw
/// [`SimplexError`] variants at every rung.
#[derive(Clone, Debug, PartialEq)]
pub enum SolveOutcome {
    /// The solve reached a proven optimum.
    Optimal(Solution),
    /// The solve ran out of budget before optimality; the partial basis is
    /// retained but no primal solution is reported.
    BudgetExhausted(BudgetReason),
    /// The solve failed for a numerical or structural reason (singular
    /// basis, infeasible instance, iteration-limit stall, …).
    Numerical(SimplexError),
}

impl SolveOutcome {
    /// Classify a raw solver result.
    pub fn from_result(r: Result<Solution, SimplexError>) -> Self {
        match r {
            Ok(sol) => SolveOutcome::Optimal(sol),
            Err(SimplexError::BudgetExhausted(reason)) => SolveOutcome::BudgetExhausted(reason),
            Err(e) => SolveOutcome::Numerical(e),
        }
    }

    /// The solution, when the outcome is optimal.
    pub fn solution(self) -> Option<Solution> {
        match self {
            SolveOutcome::Optimal(sol) => Some(sol),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_budget_is_unlimited() {
        let b = SolveBudget::default();
        assert!(b.is_unlimited());
        assert_eq!(b, SolveBudget::unlimited());
    }

    #[test]
    fn pivot_cap_is_not_unlimited() {
        assert!(!SolveBudget::with_max_pivots(5).is_unlimited());
        assert_eq!(SolveBudget::with_max_pivots(5).max_pivots, Some(5));
    }

    #[test]
    fn outcome_classifies_budget_errors() {
        let o = SolveOutcome::from_result(Err(SimplexError::BudgetExhausted(
            BudgetReason::Pivots,
        )));
        assert_eq!(o, SolveOutcome::BudgetExhausted(BudgetReason::Pivots));
        let n = SolveOutcome::from_result(Err(SimplexError::Unbounded));
        assert_eq!(n, SolveOutcome::Numerical(SimplexError::Unbounded));
        assert!(n.solution().is_none());
    }

    #[test]
    fn reasons_render_distinctly() {
        let labels: Vec<String> =
            [BudgetReason::Pivots, BudgetReason::Refactors, BudgetReason::WallClock]
                .iter()
                .map(|r| r.to_string())
                .collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
