//! Dense two-phase primal simplex with an embedded dual-simplex step,
//! full-tableau representation.
//!
//! Built for the paper's LP scale (hundreds of variables/rows) where a
//! dense tableau beats sparse machinery. The tableau keeps *all* columns —
//! including artificials — because the columns that formed the initial
//! identity are exactly `B⁻¹`, which the warm-start path uses to refresh
//! the rhs when only `b` changes between micro-batches (§5.1).

use super::problem::{LpProblem, Relation};

const TOL: f64 = 1e-9;

/// Terminal outcome of a solve that did not produce an optimum.
#[derive(Clone, Debug, thiserror::Error, PartialEq)]
pub enum SimplexError {
    /// No feasible point exists (carries the residual phase-1 objective).
    #[error("LP infeasible (phase-1 objective {0} > 0)")]
    Infeasible(f64),
    /// The objective decreases without bound along a feasible ray.
    #[error("LP unbounded below")]
    Unbounded,
    /// Pivot budget exhausted — almost certainly numerical cycling.
    #[error("iteration limit {0} exceeded (cycling?)")]
    IterLimit(usize),
    /// A caller-imposed [`super::SolveBudget`] ran out before optimality
    /// (deterministic pivot/refactor caps, or the optional wall-clock
    /// deadline — the [`super::budget::BudgetReason`] says which).
    #[error("solve budget exhausted ({0})")]
    BudgetExhausted(super::budget::BudgetReason),
    /// A basis operation broke down numerically.
    #[error("numerical breakdown: {0}")]
    Numerical(&'static str),
}

/// Optimal solution to an [`LpProblem`].
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    /// Values of the original (pre-standard-form) variables.
    pub x: Vec<f64>,
    /// Objective value at `x` (minimization sense).
    pub objective: f64,
    /// Total simplex pivots across phases (the Fig-11 warm-solve metric).
    pub iterations: usize,
    /// Row duals `y = c_B' B⁻¹` in original row order (minimization
    /// convention: `≤` rows carry `y ≤ 0`, `≥` rows `y ≥ 0`, `=` free), the
    /// other half of the optimality certificate pinned by
    /// `tests/prop_lp_certificates.rs`. When the solver expanded variable
    /// bounds into rows ([`super::bounds::expand_to_rows`]) the synthetic
    /// rows' duals trail the real ones; truncate to the original row count
    /// before checking certificates against the bounded problem.
    pub duals: Vec<f64>,
}

/// Tableau simplex solver. Retains its final state so a [`super::warm::WarmSolver`]
/// can re-solve with a changed rhs via dual simplex.
pub struct Solver {
    pub(crate) n_orig: usize,
    pub(crate) ncols: usize,
    pub(crate) m: usize,
    /// Standard-form cost vector (len ncols; artificials get 0 here but are
    /// blocked from entering after phase 1).
    pub(crate) cost: Vec<f64>,
    /// Row-major tableau, stride `ncols + 1`; last column is rhs.
    pub(crate) tab: Vec<f64>,
    /// Reduced-cost row (len ncols), plus blocked flags for artificials.
    pub(crate) red: Vec<f64>,
    pub(crate) blocked: Vec<bool>,
    pub(crate) basis: Vec<usize>,
    /// Column that held row i's +1 in the *initial* identity (slack or
    /// artificial): current tableau column `idcol[i]` is the i-th column
    /// of B⁻¹.
    pub(crate) idcol: Vec<usize>,
    /// Sign applied to each original row to make b >= 0 at build time.
    pub(crate) row_sign: Vec<f64>,
    pub(crate) iterations: usize,
    /// scratch: pivot-row snapshot + its nonzero column indices (reused
    /// across pivots — §Perf: avoids a Vec allocation per pivot and lets
    /// row updates touch only the pivot row's nonzero columns, which stays
    /// small for LPP-1's sparse constraint structure)
    scratch_row: Vec<f64>,
    scratch_nz: Vec<usize>,
}

impl Solver {
    /// Build the standard-form tableau from a problem.
    ///
    /// The tableau has no native notion of variable bounds, so finite upper
    /// bounds are first lowered into explicit `≤` rows (appended after the
    /// real rows; see [`super::bounds::expand_to_rows`]). The revised
    /// simplex handles the same bounds implicitly — the differential tests
    /// pin the two backends to identical optima.
    pub fn new(p: &LpProblem) -> Self {
        if p.has_finite_upper() {
            let (expanded, _) = super::bounds::expand_to_rows(p);
            return Self::new(&expanded);
        }
        let m = p.constraints.len();
        let n = p.num_vars;

        // column layout: [orig | slacks/surplus | artificials]
        let mut n_slack = 0usize;
        for c in &p.constraints {
            if c.rel != Relation::Eq {
                n_slack += 1;
            }
        }
        // worst case one artificial per row; allocate lazily below
        let mut cols_slack = Vec::with_capacity(m); // per-row slack col or usize::MAX
        let mut next_slack = n;
        let art_base = n + n_slack;
        let mut next_art = art_base;

        let mut row_sign = vec![1.0; m];
        let mut rows: Vec<(Vec<(usize, f64)>, f64)> = Vec::with_capacity(m);
        let mut idcol = vec![usize::MAX; m];
        let mut basis = vec![usize::MAX; m];

        for (i, c) in p.constraints.iter().enumerate() {
            let mut rel = c.rel;
            let mut rhs = c.rhs;
            let mut sign = 1.0;
            if rhs < 0.0 {
                sign = -1.0;
                rhs = -rhs;
                rel = match rel {
                    Relation::Le => Relation::Ge,
                    Relation::Ge => Relation::Le,
                    Relation::Eq => Relation::Eq,
                };
            }
            row_sign[i] = sign;
            let mut terms: Vec<(usize, f64)> =
                c.terms.iter().map(|&(v, co)| (v, sign * co)).collect();
            match rel {
                Relation::Le => {
                    let s = next_slack;
                    next_slack += 1;
                    terms.push((s, 1.0));
                    cols_slack.push(s);
                    basis[i] = s;
                    idcol[i] = s;
                }
                Relation::Ge => {
                    let s = next_slack;
                    next_slack += 1;
                    terms.push((s, -1.0));
                    cols_slack.push(s);
                    let a = next_art;
                    next_art += 1;
                    terms.push((a, 1.0));
                    basis[i] = a;
                    idcol[i] = a;
                }
                Relation::Eq => {
                    cols_slack.push(usize::MAX);
                    let a = next_art;
                    next_art += 1;
                    terms.push((a, 1.0));
                    basis[i] = a;
                    idcol[i] = a;
                }
            }
            rows.push((terms, rhs));
        }

        let ncols = next_art;
        let stride = ncols + 1;
        let mut tab = vec![0.0; m * stride];
        for (i, (terms, rhs)) in rows.iter().enumerate() {
            for &(v, co) in terms {
                tab[i * stride + v] = co;
            }
            tab[i * stride + ncols] = *rhs;
        }

        let mut cost = vec![0.0; ncols];
        cost[..n].copy_from_slice(&p.objective);
        let mut blocked = vec![false; ncols];
        for b in blocked.iter_mut().take(ncols).skip(art_base) {
            *b = true; // artificials never re-enter after phase 1
        }

        Solver {
            n_orig: n,
            ncols,
            m,
            cost,
            tab,
            red: vec![0.0; ncols],
            blocked,
            basis,
            idcol,
            row_sign,
            iterations: 0,
            scratch_row: vec![0.0; stride],
            scratch_nz: Vec::with_capacity(stride),
        }
    }

    #[inline]
    fn stride(&self) -> usize {
        self.ncols + 1
    }

    #[inline]
    pub(crate) fn rhs(&self, i: usize) -> f64 {
        self.tab[i * self.stride() + self.ncols]
    }

    /// Gaussian pivot on (row, col), updating the reduced-cost row too.
    ///
    /// Row updates iterate only the pivot row's *nonzero* columns (collected
    /// once per pivot into reusable scratch buffers): for the scheduling
    /// LPs, constraint rows keep most entries zero even after fill-in, so
    /// this turns the O(m·n) pivot into O(m·nnz) — the dominant §Perf win
    /// on the per-micro-batch path.
    pub(crate) fn pivot(&mut self, row: usize, col: usize) {
        let stride = self.stride();
        let piv = self.tab[row * stride + col];
        debug_assert!(piv.abs() > TOL, "pivot on ~0");
        let inv = 1.0 / piv;
        let (r0, r1) = (row * stride, row * stride + stride);
        // snapshot pivot row (scaled) + nonzero structure into scratch
        self.scratch_nz.clear();
        for (j, v) in self.tab[r0..r1].iter_mut().enumerate() {
            *v *= inv;
            let x = *v;
            self.scratch_row[j] = x;
            if x != 0.0 {
                self.scratch_nz.push(j);
            }
        }
        for i in 0..self.m {
            if i == row {
                continue;
            }
            let f = self.tab[i * stride + col];
            if f.abs() <= TOL {
                self.tab[i * stride + col] = 0.0;
                continue;
            }
            let base = i * stride;
            for &j in &self.scratch_nz {
                self.tab[base + j] -= f * self.scratch_row[j];
            }
            self.tab[base + col] = 0.0; // exact zero for numerical hygiene
        }
        let f = self.red[col];
        if f.abs() > TOL {
            for &j in &self.scratch_nz {
                if j < self.ncols {
                    self.red[j] -= f * self.scratch_row[j];
                }
            }
        }
        self.red[col] = 0.0;
        self.basis[row] = col;
        self.iterations += 1;
    }

    /// Recompute reduced costs `r_j = c_j - c_B' B⁻¹ A_j` for a cost vector.
    fn reset_reduced(&mut self, cost: &[f64]) {
        let stride = self.stride();
        self.red.copy_from_slice(cost);
        for i in 0..self.m {
            let cb = cost[self.basis[i]];
            if cb.abs() <= TOL {
                continue;
            }
            let base = i * stride;
            for j in 0..self.ncols {
                self.red[j] -= cb * self.tab[base + j];
            }
        }
        // basic columns have exactly zero reduced cost
        for i in 0..self.m {
            self.red[self.basis[i]] = 0.0;
        }
    }

    /// Primal simplex iterations until optimality for the current `red` row.
    fn primal_iterate(&mut self, respect_blocked: bool) -> Result<(), SimplexError> {
        let limit = 200 * (self.m + self.ncols) + 1000;
        let mut steps = 0usize;
        loop {
            steps += 1;
            if steps > limit {
                return Err(SimplexError::IterLimit(limit));
            }
            let use_bland = steps > 2 * (self.m + self.ncols);
            // entering column
            let mut enter = usize::MAX;
            let mut best = -TOL;
            for j in 0..self.ncols {
                if respect_blocked && self.blocked[j] {
                    continue;
                }
                let r = self.red[j];
                if r < best {
                    enter = j;
                    if use_bland {
                        break; // Bland: first improving index
                    }
                    best = r;
                }
            }
            if enter == usize::MAX {
                return Ok(()); // optimal
            }
            // ratio test
            let stride = self.stride();
            let mut leave = usize::MAX;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                let a = self.tab[i * stride + enter];
                if a > TOL {
                    let ratio = self.rhs(i) / a;
                    if ratio < best_ratio - TOL
                        || (ratio < best_ratio + TOL
                            && leave != usize::MAX
                            && self.basis[i] < self.basis[leave])
                    {
                        best_ratio = ratio;
                        leave = i;
                    }
                }
            }
            if leave == usize::MAX {
                return Err(SimplexError::Unbounded);
            }
            self.pivot(leave, enter);
        }
    }

    /// Dual simplex iterations: restore primal feasibility (rhs >= 0) while
    /// keeping dual feasibility (red >= 0). Used by the warm-start path.
    pub(crate) fn dual_iterate(&mut self) -> Result<(), SimplexError> {
        let limit = 200 * (self.m + self.ncols) + 1000;
        let mut steps = 0usize;
        loop {
            steps += 1;
            if steps > limit {
                return Err(SimplexError::IterLimit(limit));
            }
            // leaving row: most negative rhs
            let mut leave = usize::MAX;
            let mut most_neg = -TOL;
            for i in 0..self.m {
                let b = self.rhs(i);
                if b < most_neg {
                    most_neg = b;
                    leave = i;
                }
            }
            if leave == usize::MAX {
                return Ok(()); // primal feasible again
            }
            // entering column: min red_j / -a_ij over a_ij < 0, j not blocked
            let stride = self.stride();
            let mut enter = usize::MAX;
            let mut best = f64::INFINITY;
            for j in 0..self.ncols {
                if self.blocked[j] {
                    continue;
                }
                let a = self.tab[leave * stride + j];
                if a < -TOL {
                    let ratio = self.red[j] / -a;
                    if ratio < best - TOL || (ratio < best + TOL && enter != usize::MAX && j < enter)
                    {
                        best = ratio;
                        enter = j;
                    }
                }
            }
            if enter == usize::MAX {
                // no entering column: primal infeasible for this rhs
                return Err(SimplexError::Infeasible(-most_neg));
            }
            self.pivot(leave, enter);
        }
    }

    /// Two-phase solve.
    pub fn solve(&mut self) -> Result<Solution, SimplexError> {
        // ---- phase 1: drive artificials to zero ----
        let art_cost: Vec<f64> = (0..self.ncols).map(|j| if self.blocked[j] { 1.0 } else { 0.0 }).collect();
        let any_artificial_basic = self.basis.iter().any(|&b| self.blocked[b]);
        if any_artificial_basic {
            self.reset_reduced(&art_cost);
            self.primal_iterate(false)?; // artificials may move during phase 1
            let p1: f64 = (0..self.m)
                .filter(|&i| self.blocked[self.basis[i]])
                .map(|i| self.rhs(i))
                .sum();
            if p1 > 1e-7 {
                return Err(SimplexError::Infeasible(p1));
            }
            // pivot out any artificial stuck basic at zero level
            let stride = self.stride();
            for i in 0..self.m {
                if self.blocked[self.basis[i]] {
                    let mut found = usize::MAX;
                    for j in 0..self.ncols {
                        if !self.blocked[j] && self.tab[i * stride + j].abs() > 1e-7 {
                            found = j;
                            break;
                        }
                    }
                    if found != usize::MAX {
                        self.pivot(i, found);
                    }
                    // else: redundant row; harmless (rhs ~ 0)
                }
            }
        }
        // ---- phase 2 ----
        let cost = self.cost.clone();
        self.reset_reduced(&cost);
        self.primal_iterate(true)?;
        Ok(self.extract())
    }

    /// Current basic solution restricted to the original variables.
    pub(crate) fn extract(&self) -> Solution {
        let mut x = vec![0.0; self.n_orig];
        for i in 0..self.m {
            let b = self.basis[i];
            if b < self.n_orig {
                x[b] = self.rhs(i).max(0.0);
            }
        }
        let objective = self.cost[..self.n_orig]
            .iter()
            .zip(&x)
            .map(|(c, v)| c * v)
            .sum();
        // Row duals y' = c_B' B⁻¹: tableau column `idcol[k]` (the column
        // that held row k's +1 in the initial identity) is the k-th column
        // of B⁻¹, so y_k falls out of a weighted column sum; the build-time
        // row sign flip is undone to land in original row space.
        let stride = self.stride();
        let mut duals = vec![0.0; self.m];
        for (k, d) in duals.iter_mut().enumerate() {
            let col = self.idcol[k];
            let mut yk = 0.0;
            for i in 0..self.m {
                let cb = self.cost[self.basis[i]];
                if cb != 0.0 {
                    yk += cb * self.tab[i * stride + col];
                }
            }
            *d = self.row_sign[k] * yk;
        }
        Solution { x, objective, iterations: self.iterations, duals }
    }
}

/// One-shot convenience: build + solve.
pub fn solve(p: &LpProblem) -> Result<Solution, SimplexError> {
    Solver::new(p).solve()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::Relation::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn trivial_bounded_min() {
        // min -x0 s.t. x0 <= 4  -> x0 = 4, obj -4
        let mut p = LpProblem::new(1);
        p.set_objective(0, -1.0);
        p.add(vec![(0, 1.0)], Le, 4.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 4.0);
        assert_close(s.objective, -4.0);
    }

    #[test]
    fn classic_two_var() {
        // max 3x + 5y (min -3x -5y) s.t. x<=4, 2y<=12, 3x+2y<=18 -> (2,6), 36
        let mut p = LpProblem::new(2);
        p.set_objective(0, -3.0);
        p.set_objective(1, -5.0);
        p.add(vec![(0, 1.0)], Le, 4.0);
        p.add(vec![(1, 2.0)], Le, 12.0);
        p.add(vec![(0, 3.0), (1, 2.0)], Le, 18.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
        assert_close(s.objective, -36.0);
    }

    #[test]
    fn equality_constraints() {
        // min x+2y s.t. x + y = 10, x - y = 2 -> x=6, y=4, obj 14
        let mut p = LpProblem::new(2);
        p.set_objective(0, 1.0);
        p.set_objective(1, 2.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, 10.0);
        p.add(vec![(0, 1.0), (1, -1.0)], Eq, 2.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 6.0);
        assert_close(s.x[1], 4.0);
        assert_close(s.objective, 14.0);
    }

    #[test]
    fn ge_constraints_and_negative_rhs() {
        // min x s.t. x >= 3 (written two ways)
        let mut p = LpProblem::new(1);
        p.set_objective(0, 1.0);
        p.add(vec![(0, 1.0)], Ge, 3.0);
        p.add(vec![(0, -1.0)], Le, -3.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 3.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new(1);
        p.add(vec![(0, 1.0)], Le, 1.0);
        p.add(vec![(0, 1.0)], Ge, 2.0);
        assert!(matches!(solve(&p), Err(SimplexError::Infeasible(_))));
    }

    #[test]
    fn unbounded_detected() {
        let mut p = LpProblem::new(1);
        p.set_objective(0, -1.0);
        p.add(vec![(0, -1.0)], Le, 0.0); // -x <= 0 always true
        assert_eq!(solve(&p).unwrap_err(), SimplexError::Unbounded);
    }

    #[test]
    fn minimax_structure_like_lpp1() {
        // The paper's LPP-1 shape on a toy: 2 experts, 2 gpus,
        // EDP(e0)={0,1}, EDP(e1)={0,1}; loads 10, 2.
        // vars: x00 x01 x10 x11 t ; min t
        // x00+x10 <= t ; x01+x11 <= t ; x00+x01 = 10 ; x10+x11 = 2
        // optimum t = 6 (perfect split)
        let mut p = LpProblem::new(5);
        p.set_objective(4, 1.0);
        p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, 10.0);
        p.add(vec![(2, 1.0), (3, 1.0)], Eq, 2.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, 6.0);
        assert!(p.is_feasible(&s.x, 1e-7));
    }

    #[test]
    fn degenerate_problem_terminates() {
        // many redundant constraints through the same vertex
        let mut p = LpProblem::new(2);
        p.set_objective(0, -1.0);
        p.set_objective(1, -1.0);
        for k in 1..=8 {
            p.add(vec![(0, k as f64), (1, k as f64)], Le, 2.0 * k as f64);
        }
        let s = solve(&p).unwrap();
        assert_close(s.objective, -2.0);
    }

    #[test]
    fn solution_is_feasible_random_problems() {
        // fuzz small random LPs; solution must be feasible and no better
        // than any feasible random candidate
        use crate::rng::Rng;
        let mut rng = Rng::new(123);
        for case in 0..60 {
            let n = 2 + (case % 4);
            let m = 1 + (case % 5);
            let mut p = LpProblem::new(n);
            for j in 0..n {
                p.set_objective(j, rng.f64() * 2.0 - 0.5);
            }
            for _ in 0..m {
                let terms: Vec<(usize, f64)> =
                    (0..n).map(|j| (j, rng.f64())).collect();
                p.add(terms, Le, 1.0 + rng.f64() * 5.0);
            }
            // x = 0 is feasible (rhs > 0), so never infeasible; may be
            // unbounded if some objective coeff < 0 escapes constraints.
            match solve(&p) {
                Ok(s) => {
                    assert!(p.is_feasible(&s.x, 1e-6), "case {case}");
                    // compare against random feasible points
                    for _ in 0..20 {
                        let cand: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0).collect();
                        if p.is_feasible(&cand, 0.0) {
                            assert!(
                                s.objective <= p.objective_at(&cand) + 1e-6,
                                "case {case}: {} > {}",
                                s.objective,
                                p.objective_at(&cand)
                            );
                        }
                    }
                }
                Err(SimplexError::Unbounded) => {}
                Err(e) => panic!("case {case}: {e}"),
            }
        }
    }
}
