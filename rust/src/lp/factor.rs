//! Basis-factorization abstraction for the revised simplex.
//!
//! The solver's inner loops only ever need five linear-algebra operations
//! against the current basis matrix `B` — FTRAN (`B⁻¹·v`), BTRAN
//! (`v'·B⁻¹`), a one-row BTRAN (`e_r'·B⁻¹`), a rank-one pivot update, and
//! a full refactorization. [`Factorization`] captures exactly that
//! contract so the engine can be swapped per instance size:
//!
//! * [`super::basis::BasisInverse`] — explicit dense m×m inverse with
//!   product-form (eta) updates. O(m²) memory and O(m²) per pivot
//!   *regardless of sparsity*, but with tiny constants; the fast path for
//!   small `m` and the ablation baseline.
//! * [`super::lu::SparseLu`] — sparse LU factors with Forrest–Tomlin
//!   updates. O(nnz + fill) memory and per-pivot cost proportional to the
//!   factor sparsity, which is what keeps the per-micro-batch solve under
//!   budget once configurations pass ~128 GPUs and `m` climbs past a few
//!   hundred.
//!
//! [`FactorKind::Auto`] picks between them by row count at build time
//! ([`AUTO_DENSE_MAX_M`]); the benches force each engine explicitly.

use super::basis::{BasisError, BasisInverse};
use super::bounds::Csc;
use super::lu::SparseLu;

/// Largest row count for which [`FactorKind::Auto`] still picks the dense
/// explicit inverse. Below this, the dense engine's O(m²) eta update has
/// better constants than sparse bookkeeping; above it, fill-aware LU wins
/// on both memory (O(m²) vs O(nnz)) and per-pivot work. Revisited when the
/// LU refactorization moved to Markowitz pivoting (tighter fill shifts the
/// crossover toward smaller `m`): lowered from the PR-2 cut of 192 to one
/// 128-GPU row block, handing 129–192-row instances to the LU engine too;
/// `fig9_sched_overhead` tracks both engines per commit so the cut stays
/// honest against measured warm p50s.
pub const AUTO_DENSE_MAX_M: usize = 128;

/// Which basis-factorization engine backs a revised-simplex solve.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FactorKind {
    /// Pick by row count: dense inverse for `m ≤` [`AUTO_DENSE_MAX_M`],
    /// sparse LU beyond. The production default.
    #[default]
    Auto,
    /// Dense explicit `B⁻¹` with eta updates ([`BasisInverse`]).
    DenseInverse,
    /// Sparse LU with Forrest–Tomlin updates ([`SparseLu`]).
    SparseLu,
}

impl FactorKind {
    /// Resolve [`FactorKind::Auto`] against a concrete row count.
    pub fn resolve(self, m: usize) -> FactorKind {
        match self {
            FactorKind::Auto => {
                if m <= AUTO_DENSE_MAX_M {
                    FactorKind::DenseInverse
                } else {
                    FactorKind::SparseLu
                }
            }
            k => k,
        }
    }

    /// Build the engine in its initial (identity-basis) state.
    pub(crate) fn build(self, m: usize) -> Box<dyn Factorization> {
        match self.resolve(m) {
            FactorKind::DenseInverse => Box::new(BasisInverse::identity(m)),
            FactorKind::SparseLu => Box::new(SparseLu::identity(m)),
            FactorKind::Auto => unreachable!("resolve() never returns Auto"),
        }
    }
}

/// The basis-linear-algebra contract of the revised simplex.
///
/// Vector spaces: FTRAN outputs and the `r` of [`Factorization::btran_unit`]
/// are indexed by *basis position* (the order of the basis header);
/// FTRAN inputs and BTRAN outputs are indexed by *constraint row*. The two
/// coincide for the dense engine's explicit inverse but not for LU factors,
/// which is why the trait spells them out.
///
/// Methods take `&mut self` so implementations may reuse internal scratch
/// buffers across calls; none of them mutate the factorization itself
/// except [`Factorization::pivot_update`] and [`Factorization::refactor`].
///
/// `Send` is required because schedulers owning a solver cross thread
/// boundaries in [`crate::scheduler::schedule_layers_parallel`].
pub trait Factorization: Send {
    /// Row count `m` of the square basis.
    fn m(&self) -> usize;

    /// Whether enough update debt accumulated that the caller should
    /// refactorize. The dense engine counts eta updates (effective interval
    /// `max(REFACTOR_EVERY, m)`); the sparse engine triggers on *fill-in
    /// growth* of its factors, falling back to the same pivot-count ceiling.
    fn due_for_refactor(&self) -> bool;

    /// FTRAN against a sparse column: `out = B⁻¹ a` with `a` given as
    /// parallel (row, value) slices.
    fn ftran_sparse(&mut self, rows: &[usize], vals: &[f64], out: &mut [f64]);

    /// FTRAN against a dense vector: `out = B⁻¹ v`.
    fn ftran_dense(&mut self, v: &[f64], out: &mut [f64]);

    /// BTRAN of the basic cost vector: `out' = c_B' B⁻¹`, with `cb` given
    /// as (basis position, cost) pairs for the nonzero basic costs only.
    fn btran_costs(&mut self, cb: &[(usize, f64)], out: &mut [f64]);

    /// One-row BTRAN: `out' = e_r' B⁻¹` for basis position `r` (the pivot
    /// row needed by the dual ratio test and devex weight updates).
    fn btran_unit(&mut self, r: usize, out: &mut [f64]);

    /// Rank-one basis change: the column with sparse form (`col_rows`,
    /// `col_vals`) enters at basis position `r`; `w` is its FTRAN image
    /// `B⁻¹ a` (already computed by the simplex iteration). An `Err` means
    /// the update is numerically unusable and the caller must refactorize.
    fn pivot_update(
        &mut self,
        col_rows: &[usize],
        col_vals: &[f64],
        w: &[f64],
        r: usize,
    ) -> Result<(), BasisError>;

    /// Rebuild the factorization from the basis columns of `csc`, flushing
    /// accumulated update debt and floating-point drift.
    fn refactor(&mut self, csc: &Csc, basis: &[usize]) -> Result<(), BasisError>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_resolves_by_row_count() {
        assert_eq!(FactorKind::Auto.resolve(AUTO_DENSE_MAX_M), FactorKind::DenseInverse);
        assert_eq!(FactorKind::Auto.resolve(AUTO_DENSE_MAX_M + 1), FactorKind::SparseLu);
        assert_eq!(FactorKind::DenseInverse.resolve(10_000), FactorKind::DenseInverse);
        assert_eq!(FactorKind::SparseLu.resolve(2), FactorKind::SparseLu);
    }

    #[test]
    fn both_engines_start_as_identity() {
        for kind in [FactorKind::DenseInverse, FactorKind::SparseLu] {
            let mut f = kind.build(3);
            assert_eq!(f.m(), 3);
            let mut out = [0.0; 3];
            f.ftran_dense(&[1.0, 2.0, 3.0], &mut out);
            assert_eq!(out, [1.0, 2.0, 3.0], "{kind:?}");
            f.btran_unit(1, &mut out);
            assert_eq!(out, [0.0, 1.0, 0.0], "{kind:?}");
        }
    }
}
