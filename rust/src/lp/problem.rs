//! LP problem model: `min c'x  s.t.  row_i · x {≤,=,≥} b_i,  0 ≤ x ≤ u`.
//!
//! Upper bounds are first-class (not rows): the bounded-variable revised
//! simplex ([`super::revised`]) enforces them implicitly in its ratio tests,
//! which keeps the row count `m` — the quantity every inner loop scales
//! with — free of the ~`nx` cap rows that LPP-4 and the topology-aware
//! refinement would otherwise need. The dense tableau path expands finite
//! bounds back into `≤` rows via [`super::bounds::expand_to_rows`].

/// Row relation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Relation {
    /// `row · x ≤ rhs`
    Le,
    /// `row · x = rhs`
    Eq,
    /// `row · x ≥ rhs`
    Ge,
}

/// One constraint row, sparse.
#[derive(Clone, Debug)]
pub struct Constraint {
    /// (variable index, coefficient) pairs; indices must be unique.
    pub terms: Vec<(usize, f64)>,
    /// Relation between `terms · x` and `rhs`.
    pub rel: Relation,
    /// Right-hand-side constant.
    pub rhs: f64,
}

/// Minimization LP with non-negative, optionally upper-bounded variables.
///
/// # Example
///
/// Build a small bounded LP and solve it with the production backend
/// (maximize `3x + 5y` by minimizing its negation):
///
/// ```
/// use micromoe::lp::{LpProblem, Relation};
///
/// let mut p = LpProblem::new(2);
/// p.set_objective(0, -3.0);
/// p.set_objective(1, -5.0);
/// p.set_upper(0, 4.0); // x ≤ 4 as an implicit variable bound, not a row
/// p.set_upper(1, 6.0);
/// p.add(vec![(0, 3.0), (1, 2.0)], Relation::Le, 18.0);
///
/// let s = micromoe::lp::revised::solve(&p).unwrap();
/// assert!((s.objective - (-36.0)).abs() < 1e-6);
/// assert!((s.x[0] - 2.0).abs() < 1e-6 && (s.x[1] - 6.0).abs() < 1e-6);
/// ```
#[derive(Clone, Debug, Default)]
pub struct LpProblem {
    /// Number of structural variables.
    pub num_vars: usize,
    /// Objective coefficients (len == num_vars); minimized.
    pub objective: Vec<f64>,
    /// Constraint rows, in insertion order.
    pub constraints: Vec<Constraint>,
    /// Per-variable upper bounds (len == num_vars); `f64::INFINITY` when
    /// unbounded above. Lower bounds are always 0.
    pub upper: Vec<f64>,
}

impl LpProblem {
    /// Empty problem over `num_vars` non-negative variables.
    pub fn new(num_vars: usize) -> Self {
        LpProblem {
            num_vars,
            objective: vec![0.0; num_vars],
            constraints: Vec::new(),
            upper: vec![f64::INFINITY; num_vars],
        }
    }

    /// Set one objective coefficient (minimized).
    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    /// Append a constraint row, returning its row index.
    pub fn add(&mut self, terms: Vec<(usize, f64)>, rel: Relation, rhs: f64) -> usize {
        debug_assert!(terms.iter().all(|&(v, _)| v < self.num_vars));
        self.constraints.push(Constraint { terms, rel, rhs });
        self.constraints.len() - 1
    }

    /// Replace the rhs of a row (the warm-start update path: placement fixes
    /// the matrix, per-micro-batch loads change only `b`).
    pub fn set_rhs(&mut self, row: usize, rhs: f64) {
        self.constraints[row].rhs = rhs;
    }

    /// Set a variable's upper bound (`f64::INFINITY` removes it). Like rhs
    /// edits, bound edits leave the constraint matrix untouched, so the
    /// warm-start contract (§5.1) extends to them.
    pub fn set_upper(&mut self, var: usize, ub: f64) {
        debug_assert!(ub >= 0.0, "upper bound below the implicit lower bound 0");
        self.upper[var] = ub;
    }

    /// A variable's upper bound (`f64::INFINITY` when absent).
    pub fn upper_of(&self, var: usize) -> f64 {
        self.upper[var]
    }

    /// Whether any variable carries a finite upper bound.
    pub fn has_finite_upper(&self) -> bool {
        self.upper.iter().any(|u| u.is_finite())
    }

    /// Evaluate `row · x`.
    pub fn row_dot(&self, row: usize, x: &[f64]) -> f64 {
        self.constraints[row].terms.iter().map(|&(v, c)| c * x[v]).sum()
    }

    /// Check feasibility of a candidate point within tolerance.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.iter().any(|&v| v < -tol) {
            return false;
        }
        if x.iter().zip(&self.upper).any(|(&v, &u)| v > u + tol) {
            return false;
        }
        self.constraints.iter().enumerate().all(|(i, c)| {
            let lhs = self.row_dot(i, x);
            match c.rel {
                Relation::Le => lhs <= c.rhs + tol,
                Relation::Ge => lhs >= c.rhs - tol,
                Relation::Eq => (lhs - c.rhs).abs() <= tol,
            }
        })
    }

    /// Objective value at a point.
    pub fn objective_at(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasibility_check() {
        // min x0 s.t. x0 + x1 = 2, x0 <= 1.5
        let mut p = LpProblem::new(2);
        p.set_objective(0, 1.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Eq, 2.0);
        p.add(vec![(0, 1.0)], Relation::Le, 1.5);
        assert!(p.is_feasible(&[1.0, 1.0], 1e-9));
        assert!(p.is_feasible(&[0.0, 2.0], 1e-9));
        assert!(!p.is_feasible(&[2.0, 0.0], 1e-9)); // violates <=
        assert!(!p.is_feasible(&[1.0, 0.5], 1e-9)); // violates =
        assert!(!p.is_feasible(&[-0.1, 2.1], 1e-9)); // negative var
    }

    #[test]
    fn upper_bounds_enter_feasibility() {
        let mut p = LpProblem::new(2);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Le, 10.0);
        assert!(p.is_feasible(&[3.0, 3.0], 1e-9));
        p.set_upper(0, 2.0);
        assert!(p.has_finite_upper());
        assert!(!p.is_feasible(&[3.0, 3.0], 1e-9));
        assert!(p.is_feasible(&[2.0, 3.0], 1e-9));
        p.set_upper(0, f64::INFINITY);
        assert!(!p.has_finite_upper());
        assert!(p.is_feasible(&[3.0, 3.0], 1e-9));
    }

    #[test]
    fn objective_eval() {
        let mut p = LpProblem::new(3);
        p.set_objective(1, 2.0);
        p.set_objective(2, -1.0);
        assert_eq!(p.objective_at(&[5.0, 3.0, 4.0]), 2.0);
    }
}
