//! Linear-programming substrate.
//!
//! The paper solves its per-micro-batch scheduling LP (LPP 1 / LPP 4) with
//! HiGHS on a single CPU thread, warm-starting each micro-batch from the
//! previous solution because only the constraint *bounds* (`load_e`) change
//! while the constraint matrix (expert placement) is fixed (§5.1).
//!
//! No LP-solver crate is reachable offline, so this module implements the
//! solver from scratch:
//!
//! * [`problem`] — model: variables, `≤ / = / ≥` rows, objective sense.
//! * [`simplex`] — dense two-phase primal simplex (Dantzig pricing with a
//!   Bland fallback for anti-cycling) producing a [`simplex::Solution`]
//!   that carries its optimal basis.
//! * [`warm`] — dual-simplex re-solve for a changed rhs starting from a
//!   previous optimal basis: exactly the HiGHS warm-start pattern the paper
//!   relies on, typically finishing in a handful of pivots.
//!
//! Scale sanity: LPP 1 has `O(|E|·d)` variables and `O(|E| + |G|)` rows —
//! a few hundred of each at the paper's largest configuration (64 GPUs,
//! 256 experts), well inside dense-tableau territory.

pub mod problem;
pub mod simplex;
pub mod warm;

pub use problem::{Constraint, LpProblem, Relation};
pub use simplex::{SimplexError, Solution, Solver};
pub use warm::WarmSolver;
