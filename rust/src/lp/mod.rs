//! Linear-programming substrate.
//!
//! The paper solves its per-micro-batch scheduling LP (LPP 1 / LPP 4) with
//! HiGHS on a single CPU thread, warm-starting each micro-batch from the
//! previous solution because only the constraint *bounds* (`load_e`) change
//! while the constraint matrix (expert placement) is fixed (§5.1). No
//! LP-solver crate is reachable offline, so this module implements the
//! solvers from scratch.
//!
//! # Architecture: why a bounded-variable *revised* simplex
//!
//! The hot path must stay under ~1 ms at 64 GPUs / 256 experts (Fig. 9).
//! Two structural facts about the scheduling LPs make the revised method
//! the right shape:
//!
//! 1. **Per-pivot cost scales with `m`, and half of LPP-4's rows are
//!    bounds in disguise.** The CommAware/TopoAware formulations carry
//!    `l_e^g ≤ input_e^g` and `n_e^ν ≤ node_input_e^ν` rows — one per
//!    replica — that involve a *single* variable each. [`revised`] treats
//!    them as implicit variable bounds (`0 ≤ x_j ≤ u_j`) enforced in the
//!    ratio tests, removing ~`nx` (resp. ~`2·nx`) rows from `m`. A
//!    nonbasic variable rests at either bound and may "bound-flip" without
//!    any basis change.
//! 2. **The tableau wastes work on columns nobody asks about.** The dense
//!    tableau updates all `ncols` columns every pivot (O(m·ncols)); the
//!    revised method keeps the matrix in CSC form ([`bounds::Csc`]),
//!    maintains the basis behind the [`Factorization`] trait — an explicit
//!    `B⁻¹` ([`basis::BasisInverse`]) with eta updates for small `m`,
//!    sparse LU with Forrest–Tomlin updates ([`lu::SparseLu`]) beyond —
//!    and prices columns lazily, O(nnz) per priced column.
//!
//! # Warm-start invariants (§5.1)
//!
//! Between micro-batches the constraint matrix is frozen; only rhs entries
//! and variable bounds move. Both backends therefore guarantee:
//!
//! * the retained basis stays *dual-feasible* under rhs/bound edits, so a
//!   re-solve is `x_B = B⁻¹(b − A_U u)` refresh + dual-simplex repair —
//!   run by the revised backend as a *long-step* dual with the
//!   bound-flipping ratio test (every boxed column the dual step crosses
//!   flips in one batched `x_B` update before the pivot; see [`revised`]);
//! * a warm failure of any kind (including `Infeasible`, which a stale
//!   basis can report spuriously) falls back to a cold solve without
//!   losing the ability to warm-start later batches;
//! * [`Solution::iterations`] counts pivots identically on both paths, so
//!   Fig. 11's warm-vs-cold pivot ablation is backend-independent; the
//!   finer [`SolveStats`] counters (dual pivots, bound flips,
//!   refactorizations) attribute the warm-repair work per engine;
//! * every optimum carries its KKT certificate ([`Solution::duals`] plus
//!   reduced costs derived from it), pinned for all backends by
//!   `tests/prop_lp_certificates.rs`.
//!
//! # Scaling knobs (past ~128 GPUs)
//!
//! Two further engine choices keep the per-pivot cost from growing with
//! the configuration:
//!
//! * **Pricing** ([`Pricing`]): Dantzig pricing sweeps every nonbasic
//!   column per pivot; devex reference-framework pricing with a partial
//!   candidate-list sweep both cuts the pivot *count* (steepest-edge-like
//!   entering choices) and makes most pricing passes touch only a short
//!   list of columns.
//! * **Factorization** ([`FactorKind`], behind the [`Factorization`]
//!   trait): the dense explicit `B⁻¹` is O(m²) memory and O(m²) per eta
//!   update regardless of sparsity — fine for small `m`, a wall past a
//!   few hundred rows. Sparse LU factors with Forrest–Tomlin updates
//!   ([`lu`]) scale with fill instead, refactorize on fill *growth*
//!   rather than a fixed pivot count, and keep that fill low by
//!   refactorizing with Markowitz threshold pivoting.
//!
//! # Modules
//!
//! * [`problem`] — model: variables, `≤ / = / ≥` rows, upper bounds,
//!   objective sense.
//! * [`bounds`] — bound↔row lowering shared by the backends, plus the CSC
//!   matrix type.
//! * [`budget`] — deterministic per-solve budgets ([`SolveBudget`]) and the
//!   typed [`SolveOutcome`] the degradation ladder consumes.
//! * [`factor`] — the [`Factorization`] trait + engine selection.
//! * [`basis`] — dense explicit basis inverse (eta updates, Gauss–Jordan
//!   refactorization); the small-`m` fast path.
//! * [`lu`] — sparse LU with Forrest–Tomlin updates; the large-`m` path.
//! * [`revised`] — bounded-variable revised simplex (the default backend),
//!   including both pricing rules.
//! * [`simplex`] — dense two-phase full-tableau primal simplex (ablation
//!   baseline; bounds are expanded into rows).
//! * [`warm`] — [`WarmSolver`]: backend selection + the warm-start state
//!   machine.

pub mod basis;
pub mod bounds;
pub mod budget;
pub mod factor;
pub mod lu;
pub mod problem;
pub mod revised;
pub mod simplex;
pub mod warm;

pub use budget::{BudgetReason, SolveBudget, SolveOutcome};
pub use factor::{FactorKind, Factorization};
pub use problem::{Constraint, LpProblem, Relation};
pub use revised::{Pricing, RevisedSolver, SolveStats};
pub use simplex::{SimplexError, Solution, Solver};
pub use warm::{SolverKind, WarmSolver};
