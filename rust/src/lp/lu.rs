//! Sparse LU basis factors with Forrest–Tomlin updates.
//!
//! The dense [`super::basis::BasisInverse`] holds `B⁻¹` explicitly — O(m²)
//! memory and O(m²) per eta update no matter how sparse the basis is. For
//! the scheduling LPs the basis *is* sparse (a handful of nonzeros per
//! column at any GPU count), so past ~128 GPUs the right representation is
//! the factorization itself:
//!
//! ```text
//!   R · P · E · B  =  U        ⇔        B⁻¹ = U⁻¹ · R · P · E
//! ```
//!
//! * `E` — row-elimination operations from Gaussian elimination with
//!   Markowitz pivoting (threshold partial pivoting, `u ≈ 0.1`; see
//!   [`SparseLu`]'s `refactor`), kept as a sparse op list in
//!   constraint-row space;
//! * `P` — the row permutation (`pr`), mapping each U position ("slot") to
//!   the constraint row that was pivotal for it;
//! * `R` — Forrest–Tomlin update operations in slot space, appended by
//!   [`Factorization::pivot_update`];
//! * `U` — sparse upper triangular, stored *row-wise* with an explicit
//!   logical column order (`lorder`), so both triangular solves and the
//!   Forrest–Tomlin row elimination walk existing row lists.
//!
//! A Forrest–Tomlin update replaces basis column `p`: the entering
//! column's partial FTRAN image (the *spike*) becomes a new last column of
//! `U`, the stale row `p` of `U` is eliminated against the rows below it
//! (each elimination appending one op to `R`), and the logical order
//! cyclically shifts `p` to the end. Cost per update is proportional to
//! the touched fill, not m².
//!
//! Unlike the dense engine's fixed `max(REFACTOR_EVERY, m)` eta interval,
//! [`Factorization::due_for_refactor`] here triggers on **fill-in growth**: a
//! refactorization is requested once the factors (U nonzeros plus the E/R
//! op lists) grow past a constant multiple of their post-factorization
//! size, with the dense engine's pivot-count ceiling kept only as a
//! backstop. Fill, not pivot count, is what actually degrades FTRAN/BTRAN
//! cost and numerical quality here.

use super::basis::{BasisError, REFACTOR_EVERY};
use super::bounds::Csc;
use super::factor::Factorization;

/// Pivots smaller than this are numerically unusable (matches the dense
/// engine's threshold so the two report singularity consistently).
const PIVOT_TOL: f64 = 1e-10;

/// Entries below this magnitude are dropped when rows are combined —
/// cancellation dust that would otherwise masquerade as fill.
const DROP_TOL: f64 = 1e-14;

/// Threshold-partial-pivoting relaxation factor `u` for Markowitz
/// pivoting: an entry is an acceptable pivot when `|a_ij| ≥ u · max_i
/// |a_ij|` over its (active) column. The classic compromise value — small
/// enough that the fill-minimizing Markowitz choice is rarely vetoed,
/// large enough to bound element growth.
const MARKOWITZ_U: f64 = 0.1;

/// How many candidate columns (searched in ascending active-count order)
/// the Markowitz pivot search examines before settling, Suhl-style; more
/// search buys marginally less fill at linear search cost.
const MARKOWITZ_SEARCH: usize = 8;

/// One sparse row operation `x[target] -= mult * x[source]`, used both for
/// the elimination file `E` (constraint-row space) and the Forrest–Tomlin
/// file `R` (slot space).
#[derive(Clone, Copy, Debug)]
struct RowOp {
    target: usize,
    source: usize,
    mult: f64,
}

/// Sparse LU factorization of the basis with Forrest–Tomlin updates.
#[derive(Clone, Debug)]
pub struct SparseLu {
    m: usize,
    /// Elimination ops (`E`), applied in order to row-space vectors.
    lops: Vec<RowOp>,
    /// `pr[slot]` — constraint row pivotal for U slot `slot` (the `P` map).
    pr: Vec<usize>,
    /// Row-wise U: `urows[slot]` holds (column slot, value) entries, all at
    /// columns logically after `slot`; the diagonal lives in `udiag`.
    urows: Vec<Vec<(usize, f64)>>,
    /// U diagonal per slot.
    udiag: Vec<f64>,
    /// Logical column order: `lorder[l]` = slot at triangular position `l`.
    lorder: Vec<usize>,
    /// Inverse of `lorder`: `lpos[slot]` = logical position.
    lpos: Vec<usize>,
    /// Forrest–Tomlin ops (`R`), applied in order to slot-space vectors.
    rops: Vec<RowOp>,
    /// Factor size (U nnz + op-file lengths) right after refactorization —
    /// the baseline for the fill-growth refactor trigger.
    base_size: usize,
    /// Pivot updates since the last refactorization.
    updates: usize,
    /// scratch, length m (row space / slot space).
    work: Vec<f64>,
    work2: Vec<f64>,
}

impl SparseLu {
    /// Identity factorization (the initial slack/artificial basis).
    pub fn identity(m: usize) -> Self {
        SparseLu {
            m,
            lops: Vec::new(),
            pr: (0..m).collect(),
            urows: vec![Vec::new(); m],
            udiag: vec![1.0; m],
            lorder: (0..m).collect(),
            lpos: (0..m).collect(),
            rops: Vec::new(),
            base_size: m,
            updates: 0,
            work: vec![0.0; m],
            work2: vec![0.0; m],
        }
    }

    /// Current factor size: U nonzeros (incl. diagonal) plus both op files.
    fn size(&self) -> usize {
        self.m + self.urows.iter().map(Vec::len).sum::<usize>() + self.lops.len() + self.rops.len()
    }

    /// Shared tail of both FTRAN entry points: `self.work` holds the dense
    /// row-space input; result lands in `out` (basis-position space).
    fn solve_from_work(&mut self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m);
        // E: elimination ops in row space
        for op in &self.lops {
            let t = self.work[op.source];
            if t != 0.0 {
                self.work[op.target] -= op.mult * t;
            }
        }
        // P: gather rows into slots
        for s in 0..self.m {
            self.work2[s] = self.work[self.pr[s]];
        }
        // R: Forrest–Tomlin ops in slot space
        for op in &self.rops {
            let t = self.work2[op.source];
            if t != 0.0 {
                self.work2[op.target] -= op.mult * t;
            }
        }
        // U: back substitution, logically last column first. Row `s` holds
        // entries only at logically later columns, whose solution values
        // are already final when `s` is reached.
        for &s in self.lorder.iter().rev() {
            let mut v = self.work2[s];
            for &(c, u) in &self.urows[s] {
                v -= u * out[c];
            }
            out[s] = v / self.udiag[s];
        }
    }

    /// Shared tail of both BTRAN entry points: `self.work2` holds the
    /// slot-space input `c`; computes `out' = c' U⁻¹ R P E` (row space).
    fn btran_from_slots(&mut self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m);
        // U⁻ᵀ: forward substitution in logical order, scatter style — once
        // z[s] is final, push its contribution into every later column.
        for &s in &self.lorder {
            let z = self.work2[s] / self.udiag[s];
            self.work2[s] = z;
            if z != 0.0 {
                for &(c, u) in &self.urows[s] {
                    self.work2[c] -= u * z;
                }
            }
        }
        // Rᵀ: transposed ops, reverse order
        for op in self.rops.iter().rev() {
            let t = self.work2[op.target];
            if t != 0.0 {
                self.work2[op.source] -= op.mult * t;
            }
        }
        // Pᵀ: scatter slots back onto constraint rows
        self.work.fill(0.0);
        for s in 0..self.m {
            self.work[self.pr[s]] = self.work2[s];
        }
        // Eᵀ: transposed ops, reverse order
        for op in self.lops.iter().rev() {
            let t = self.work[op.target];
            if t != 0.0 {
                self.work[op.source] -= op.mult * t;
            }
        }
        out.copy_from_slice(&self.work);
    }
}

impl Factorization for SparseLu {
    fn m(&self) -> usize {
        self.m
    }

    fn due_for_refactor(&self) -> bool {
        if self.updates == 0 {
            return false;
        }
        // Fill-growth trigger: refactor once the factors outgrow their
        // post-factorization size by 2× (plus slack so tiny instances
        // don't thrash); pivot count kept only as a drift backstop.
        self.size() > 2 * self.base_size + 64 || self.updates >= REFACTOR_EVERY.max(self.m)
    }

    fn ftran_sparse(&mut self, rows: &[usize], vals: &[f64], out: &mut [f64]) {
        self.work.fill(0.0);
        for (&i, &a) in rows.iter().zip(vals) {
            self.work[i] += a;
        }
        self.solve_from_work(out);
    }

    fn ftran_dense(&mut self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.m);
        self.work.copy_from_slice(v);
        self.solve_from_work(out);
    }

    fn btran_costs(&mut self, cb: &[(usize, f64)], out: &mut [f64]) {
        self.work2.fill(0.0);
        for &(k, c) in cb {
            self.work2[k] += c;
        }
        self.btran_from_slots(out);
    }

    fn btran_unit(&mut self, r: usize, out: &mut [f64]) {
        self.work2.fill(0.0);
        self.work2[r] = 1.0;
        self.btran_from_slots(out);
    }

    /// Forrest–Tomlin update: basis position `r` takes the column with
    /// sparse form (`col_rows`, `col_vals`), whose FTRAN image is `w`.
    fn pivot_update(
        &mut self,
        _col_rows: &[usize],
        _col_vals: &[f64],
        w: &[f64],
        r: usize,
    ) -> Result<(), BasisError> {
        let m = self.m;
        debug_assert_eq!(w.len(), m);
        // The spike — the entering column pushed through E, P and R but not
        // U — is recovered from the already-available FTRAN image as
        // `spike = U·w`, O(nnz(U)) with no extra solves.
        for s in 0..m {
            let mut v = self.udiag[s] * w[s];
            for &(c, u) in &self.urows[s] {
                v += u * w[c];
            }
            self.work2[s] = if v.abs() <= DROP_TOL { 0.0 } else { v };
        }
        let lp = self.lpos[r];
        // Drop the stale column `r` from all logically earlier rows (later
        // rows cannot reference it — U is triangular).
        for &t in &self.lorder[..lp] {
            self.urows[t].retain(|&(c, _)| c != r);
        }
        // The stale row `r` becomes the spike row to eliminate; pull it out.
        let stale = std::mem::take(&mut self.urows[r]);
        self.work.fill(0.0);
        for &(c, v) in &stale {
            self.work[c] = v;
        }
        // Column `r` moves to the logical end; its new entries are the
        // spike values of every other slot (all logically before it now).
        for t in 0..m {
            if t != r && self.work2[t] != 0.0 {
                self.urows[t].push((r, self.work2[t]));
            }
        }
        let mut dlast = self.work2[r];
        // Eliminate the spike row against the rows logically after `lp`,
        // ascending — each elimination appends one op to R and folds the
        // row's last-column (spike) entry into the new diagonal.
        for li in (lp + 1)..m {
            let t = self.lorder[li];
            let v = self.work[t];
            if v.abs() <= DROP_TOL {
                continue;
            }
            self.work[t] = 0.0;
            let mult = v / self.udiag[t];
            self.rops.push(RowOp { target: r, source: t, mult });
            for &(c, u) in &self.urows[t] {
                if c == r {
                    dlast -= mult * u;
                } else {
                    self.work[c] -= mult * u;
                }
            }
        }
        if dlast.abs() < PIVOT_TOL {
            // The caller refactorizes from the updated basis header.
            return Err(BasisError::TinyPivot(dlast));
        }
        self.udiag[r] = dlast;
        // urows[r] stays empty: the last logical row has no off-diagonals.
        self.lorder.remove(lp);
        self.lorder.push(r);
        for (l, &s) in self.lorder.iter().enumerate() {
            self.lpos[s] = l;
        }
        self.updates += 1;
        Ok(())
    }

    /// Sparse Gaussian elimination with **Markowitz pivoting**: each step
    /// picks the entry minimizing the Markowitz count `(r_i − 1)(c_j − 1)`
    /// (the worst-case fill that pivot can create) among entries passing
    /// threshold partial pivoting (`|a_ij| ≥ u · max_i |a_ij|` over the
    /// active column, `u` = `MARKOWITZ_U` = 0.1). Candidate columns are
    /// visited in ascending active-count order via lazily maintained
    /// count buckets, and the search stops Suhl-style after
    /// `MARKOWITZ_SEARCH` eligible columns (immediately on a fill-free
    /// cost-0 pivot). This replaces the PR-2 static ascending-nnz column
    /// order, which fixed the order up front and so went fill-blind the
    /// moment elimination changed the row/column counts it was sorted by.
    fn refactor(&mut self, csc: &Csc, basis: &[usize]) -> Result<(), BasisError> {
        let m = self.m;
        debug_assert_eq!(basis.len(), m);
        // Working rows of B in (column slot, value) form, plus a
        // column→candidate-rows index (stale-tolerant) and *exact* active
        // entry counts per column, maintained under fill-in/cancellation.
        let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
        let mut colrows: Vec<Vec<usize>> = vec![Vec::new(); m];
        let mut cnt = vec![0usize; m];
        for (slot, &j) in basis.iter().enumerate() {
            let (ri, rv) = csc.col(j);
            for (&i, &a) in ri.iter().zip(rv) {
                if a != 0.0 {
                    rows[i].push((slot, a));
                    colrows[slot].push(i);
                    cnt[slot] += 1;
                }
            }
        }
        // count buckets over columns; entries go stale when a count moves
        // and are skipped (and dropped) when their bucket is next scanned.
        // A column whose count oscillates gets pushed more than once, so a
        // per-step visited stamp dedups scans (and drops the extra copies)
        // — otherwise duplicates would eat the Suhl search budget.
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); m + 1];
        for s in 0..m {
            buckets[cnt[s]].push(s);
        }
        let mut seen_step = vec![usize::MAX; m];

        let mut lops: Vec<RowOp> = Vec::new();
        let mut pr = vec![usize::MAX; m];
        let mut urows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
        let mut udiag = vec![0.0; m];
        let mut row_done = vec![false; m];
        let mut col_done = vec![false; m];
        let mut lorder = Vec::with_capacity(m);
        let mut lpos = vec![usize::MAX; m];
        // dense scratch for sparse row combines
        let mut acc = vec![0.0; m];
        let mut inpat = vec![false; m];
        let mut in_old = vec![false; m];
        let mut pattern: Vec<usize> = Vec::new();
        // scratch: live (row, value) entries of the column under search
        // (collected once per column, reused by the colmax and threshold
        // passes; a cancel-then-refill column can list a row twice in
        // `colrows`, which merely re-reads the same live entry)
        let mut entries: Vec<(usize, f64)> = Vec::new();

        // live value of column `s` in row `i`, if any
        let entry_in = |rows: &[Vec<(usize, f64)>], i: usize, s: usize| -> Option<f64> {
            rows[i].iter().find(|&&(c, _)| c == s).map(|&(_, v)| v)
        };

        for step in 0..m {
            // ---- Markowitz pivot search over the sparsest columns ----
            let mut prow = usize::MAX;
            let mut pcol = usize::MAX;
            let mut best_cost = usize::MAX;
            let mut best_val = 0.0f64;
            let mut max_rejected = 0.0f64;
            let mut searched = 0usize;
            'nnz: for nnz in 1..=m {
                // Note: a later bucket can still hide a *better* pivot (a
                // column of any count meeting a singleton row costs 0), so
                // no count-based cutoff is sound when only columns are
                // scanned in count order; the search budget below and the
                // cost-0 early exit bound the work instead.
                let bucket = std::mem::take(&mut buckets[nnz]);
                let mut keep: Vec<usize> = Vec::with_capacity(bucket.len());
                for (idx, &s) in bucket.iter().enumerate() {
                    if col_done[s] || cnt[s] != nnz || seen_step[s] == step {
                        continue; // stale or duplicate: drop this copy
                    }
                    seen_step[s] = step;
                    keep.push(s);
                    entries.clear();
                    let mut colmax = 0.0f64;
                    for &i in &colrows[s] {
                        if !row_done[i] {
                            if let Some(v) = entry_in(&rows, i, s) {
                                entries.push((i, v));
                                colmax = colmax.max(v.abs());
                            }
                        }
                    }
                    if colmax < PIVOT_TOL {
                        max_rejected = max_rejected.max(colmax);
                        continue;
                    }
                    searched += 1;
                    for &(i, v) in &entries {
                        if v.abs() < MARKOWITZ_U * colmax || v.abs() < PIVOT_TOL {
                            continue;
                        }
                        let cost = (rows[i].len() - 1) * (cnt[s] - 1);
                        if cost < best_cost || (cost == best_cost && v.abs() > best_val.abs()) {
                            best_cost = cost;
                            best_val = v;
                            prow = i;
                            pcol = s;
                        }
                    }
                    if searched >= MARKOWITZ_SEARCH && best_cost != usize::MAX {
                        keep.extend(bucket[idx + 1..].iter().copied().filter(|&s2| {
                            !col_done[s2] && cnt[s2] == nnz && seen_step[s2] != step
                        }));
                        buckets[nnz] = keep;
                        break 'nnz;
                    }
                }
                buckets[nnz] = keep;
                if best_cost == 0 {
                    break; // a fill-free pivot cannot be beaten
                }
            }
            if prow == usize::MAX {
                return Err(BasisError::Singular(max_rejected, step));
            }
            let s = pcol;
            col_done[s] = true;
            let pivot_row = std::mem::take(&mut rows[prow]);
            let piv = best_val;
            // the pivot row leaves the active set: its columns lose a member
            for &(c, _) in &pivot_row {
                if !col_done[c] {
                    cnt[c] -= 1;
                    buckets[cnt[c]].push(c);
                }
            }
            // eliminate column s from every other unpivoted row holding it
            let cands = std::mem::take(&mut colrows[s]);
            for &i in &cands {
                if row_done[i] || i == prow {
                    continue;
                }
                let Some(a) = entry_in(&rows, i, s) else {
                    continue; // stale candidate (entry cancelled earlier)
                };
                let mult = a / piv;
                lops.push(RowOp { target: i, source: prow, mult });
                // rows[i] -= mult * pivot_row, dropping column s
                pattern.clear();
                for &(c, v) in &rows[i] {
                    if c == s {
                        continue;
                    }
                    acc[c] = v;
                    inpat[c] = true;
                    in_old[c] = true;
                    pattern.push(c);
                }
                for &(c, v) in &pivot_row {
                    if c == s {
                        continue;
                    }
                    if !inpat[c] {
                        acc[c] = 0.0;
                        inpat[c] = true;
                        pattern.push(c);
                        colrows[c].push(i); // fill-in: index the new entry
                    }
                    acc[c] -= mult * v;
                }
                let mut next = Vec::with_capacity(pattern.len());
                for &c in &pattern {
                    let live = acc[c].abs() > DROP_TOL;
                    if live {
                        next.push((c, acc[c]));
                    }
                    // exact count maintenance: fill-in vs cancellation
                    if !col_done[c] && live != in_old[c] {
                        if live {
                            cnt[c] += 1;
                        } else {
                            cnt[c] -= 1;
                        }
                        buckets[cnt[c]].push(c);
                    }
                    inpat[c] = false;
                    in_old[c] = false;
                }
                rows[i] = next;
            }
            // pivot row becomes U row for slot s (minus the diagonal)
            pr[s] = prow;
            udiag[s] = piv;
            urows[s] = pivot_row.into_iter().filter(|&(c, _)| c != s).collect();
            row_done[prow] = true;
            lpos[s] = lorder.len();
            lorder.push(s);
        }

        self.lops = lops;
        self.pr = pr;
        self.urows = urows;
        self.udiag = udiag;
        self.lorder = lorder;
        self.lpos = lpos;
        self.rops.clear();
        self.updates = 0;
        self.base_size = self.size();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::basis::BasisInverse;
    use crate::rng::Rng;

    /// Random sparse nonsingular-ish CSC: `extra` columns beyond an m×m
    /// identity block, with random sprinkled entries.
    fn random_csc(rng: &mut Rng, m: usize, extra: usize) -> Csc {
        let mut cols: Vec<Vec<(usize, f64)>> = Vec::new();
        for i in 0..m {
            cols.push(vec![(i, 1.0)]);
        }
        for _ in 0..extra {
            let mut col = Vec::new();
            for i in 0..m {
                if rng.f64() < 0.3 {
                    col.push((i, rng.f64() * 4.0 - 2.0));
                }
            }
            if col.is_empty() {
                col.push((rng.below(m as u64) as usize, 1.0 + rng.f64()));
            }
            cols.push(col);
        }
        Csc::from_columns(m, cols)
    }

    fn assert_vec_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    /// The LU engine must agree with the dense inverse on every trait
    /// operation, across refactorizations and Forrest–Tomlin updates.
    #[test]
    fn lu_matches_dense_inverse_under_updates() {
        let mut rng = Rng::new(77);
        for trial in 0..20 {
            let m = 3 + (trial % 6);
            let csc = random_csc(&mut rng, m, 2 * m);
            let mut basis: Vec<usize> = (0..m).collect(); // identity block
            let mut lu = SparseLu::identity(m);
            let mut dense = BasisInverse::identity(m);
            lu.refactor(&csc, &basis).unwrap();
            dense.refactor(&csc, &basis).unwrap();

            let mut wl = vec![0.0; m];
            let mut wd = vec![0.0; m];
            for round in 0..3 * m {
                // random replacement: some non-identity column into a slot
                let j = m + rng.below((csc.ncols - m) as u64) as usize;
                let r = rng.below(m as u64) as usize;
                let (cr, cv) = csc.col(j);
                lu.ftran_sparse(cr, cv, &mut wl);
                dense.ftran_sparse(cr, cv, &mut wd);
                assert_vec_close(&wl, &wd, 1e-6, "ftran");
                if wl[r].abs() < 1e-6 {
                    continue; // would be a terrible pivot for both engines
                }
                basis[r] = j;
                let ok_lu = lu.pivot_update(cr, cv, &wl, r).is_ok();
                let ok_dense = dense.update(&wd, r).is_ok();
                assert!(ok_dense, "trial {trial} round {round}: dense eta refused");
                if !ok_lu {
                    lu.refactor(&csc, &basis).unwrap();
                }

                // compare all operations on fresh random vectors
                let v: Vec<f64> = (0..m).map(|_| rng.f64() * 2.0 - 1.0).collect();
                let mut ol = vec![0.0; m];
                let mut od = vec![0.0; m];
                lu.ftran_dense(&v, &mut ol);
                dense.ftran_dense(&v, &mut od);
                assert_vec_close(&ol, &od, 1e-6, "ftran_dense");
                let cb: Vec<(usize, f64)> =
                    (0..m).filter(|_| rng.f64() < 0.5).map(|k| (k, rng.f64())).collect();
                lu.btran_costs(&cb, &mut ol);
                dense.btran_costs(&cb, &mut od);
                assert_vec_close(&ol, &od, 1e-6, "btran_costs");
                let r2 = rng.below(m as u64) as usize;
                lu.btran_unit(r2, &mut ol);
                od.copy_from_slice(dense.row(r2));
                assert_vec_close(&ol, &od, 1e-6, "btran_unit");

                if lu.due_for_refactor() {
                    lu.refactor(&csc, &basis).unwrap();
                }
                if dense.due_for_refactor() {
                    dense.refactor(&csc, &basis).unwrap();
                }
            }
        }
    }

    #[test]
    fn refactor_then_solve_roundtrips() {
        // B = [[2,1],[0,3]] (csc cols), check B * ftran(b) == b
        let csc = Csc::from_columns(2, vec![vec![(0, 2.0)], vec![(0, 1.0), (1, 3.0)]]);
        let mut lu = SparseLu::identity(2);
        lu.refactor(&csc, &[0, 1]).unwrap();
        let mut x = [0.0; 2];
        lu.ftran_dense(&[2.0, 3.0], &mut x);
        // B x = [2x0 + x1, 3x1] must equal [2, 3]
        assert!((2.0 * x[0] + x[1] - 2.0).abs() < 1e-12);
        assert!((3.0 * x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn singular_basis_detected() {
        let csc = Csc::from_columns(2, vec![vec![(0, 1.0)], vec![(0, 2.0)]]);
        let mut lu = SparseLu::identity(2);
        assert!(matches!(lu.refactor(&csc, &[0, 1]), Err(BasisError::Singular(..))));
    }

    /// Markowitz ordering must keep an arrowhead matrix fill-free: pivoting
    /// the dense row/column first (as any count-blind order risks) fills the
    /// whole trailing block, O(m²) factor entries instead of O(m). Also
    /// cross-checks the factors against the dense inverse.
    #[test]
    fn markowitz_keeps_arrowhead_fill_linear() {
        let m = 24;
        // column j < m-1: diagonal + a last-row entry; last column: dense
        let mut cols: Vec<Vec<(usize, f64)>> = (0..m - 1)
            .map(|j| vec![(j, 2.0 + j as f64 * 0.1), (m - 1, 0.5)])
            .collect();
        cols.push((0..m).map(|i| (i, if i == m - 1 { 4.0 } else { 0.7 })).collect());
        let csc = Csc::from_columns(m, cols);
        let basis: Vec<usize> = (0..m).collect();
        let mut lu = SparseLu::identity(m);
        lu.refactor(&csc, &basis).unwrap();
        assert!(
            lu.size() < 5 * m,
            "arrowhead fill blew up: {} factor entries for m = {m}",
            lu.size()
        );
        let mut dense = BasisInverse::identity(m);
        dense.refactor(&csc, &basis).unwrap();
        let v: Vec<f64> = (0..m).map(|i| (i as f64).sin()).collect();
        let mut ol = vec![0.0; m];
        let mut od = vec![0.0; m];
        lu.ftran_dense(&v, &mut ol);
        dense.ftran_dense(&v, &mut od);
        assert_vec_close(&ol, &od, 1e-8, "arrowhead ftran");
        lu.btran_unit(m - 1, &mut ol);
        od.copy_from_slice(dense.row(m - 1));
        assert_vec_close(&ol, &od, 1e-8, "arrowhead btran");
    }

    #[test]
    fn fill_growth_triggers_refactor_request() {
        // Dense replacement columns grow U fill and the R file; the
        // fill-growth trigger must fire long before the dense engine's
        // pivot-count ceiling of max(REFACTOR_EVERY, m) updates.
        let m = 12;
        // columns m+k are dense and diagonally dominant, so every prefix
        // of replacements keeps the basis nonsingular with solid pivots
        let cols: Vec<Vec<(usize, f64)>> = (0..m)
            .map(|i| vec![(i, 1.0)])
            .chain((0..m).map(|k| {
                (0..m)
                    .map(|i| (i, if i == k { 3.0 } else { 0.2 / (1.0 + (i + k) as f64) }))
                    .collect()
            }))
            .collect();
        let csc = Csc::from_columns(m, cols);
        let mut lu = SparseLu::identity(m);
        let mut w = vec![0.0; m];
        let mut fired_after = None;
        for r in 0..m {
            let (cr, cv) = csc.col(m + r);
            lu.ftran_sparse(cr, cv, &mut w);
            assert!(w[r].abs() > 1e-9, "diagonally dominant pivot vanished");
            lu.pivot_update(cr, cv, &w, r).unwrap();
            if lu.due_for_refactor() {
                fired_after = Some(r + 1);
                break;
            }
        }
        let fired = fired_after.expect("fill-growth trigger never fired");
        assert!(
            fired < REFACTOR_EVERY.max(m),
            "trigger fired at {fired}, no earlier than the pivot-count ceiling"
        );
    }
}
