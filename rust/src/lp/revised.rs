//! Bounded-variable revised simplex — the per-micro-batch hot path.
//!
//! Where the dense tableau pays O(m · ncols) per pivot over a tableau that
//! retains every slack and artificial column, this solver keeps the
//! constraint matrix in CSC form ([`super::bounds::Csc`]), maintains the
//! basis behind the [`Factorization`] trait — a dense explicit `B⁻¹` for
//! small `m`, sparse LU factors with Forrest–Tomlin updates beyond
//! ([`super::factor::FactorKind`]) — and prices columns lazily. Simple
//! upper bounds `0 ≤ x_j ≤ u_j` are enforced *implicitly* in the ratio
//! tests — a bounded nonbasic variable rests at either bound and can
//! "bound-flip" without a basis change — so LPP-4's `l ≤ input` cap rows
//! and the topology-aware `n ≤ node_input` rows never enter `m`, the
//! quantity every inner loop scales with.
//!
//! # Pricing ([`Pricing`])
//!
//! * [`Pricing::Dantzig`] — full nonbasic sweep per pivot, most attractive
//!   reduced cost. O(nnz(A)) per pivot regardless of how many pivots the
//!   chosen column saves; the PR-1 baseline, kept for ablations.
//! * [`Pricing::Devex`] — reference-framework devex weights (Forrest &
//!   Goldfarb's practical approximation of steepest edge) scored as
//!   `d_j² / w_j`, over a **partial candidate-list sweep**: a short list of
//!   attractive columns is retained between pivots and re-priced first; a
//!   full sweep runs only when the list dries up. Weight updates are
//!   applied to the candidate list only (partial devex) and the reference
//!   framework resets when any weight outgrows `DEVEX_RESET`. The dual
//!   iterations use the mirror-image device: leaving rows are selected by
//!   `violation² / w_i` with dual-devex row weights that update in O(m)
//!   from quantities the pivot already computed.
//!
//! Anti-cycling: after a stall both rules fall back to Bland's first-index
//! sweep, exactly as before.
//!
//! # Long-step dual simplex (BFRT)
//!
//! Warm start (§5.1): between micro-batches only `b` and the bounds move,
//! so the previous optimal basis stays dual-feasible; [`RevisedSolver::warm_resolve`]
//! refreshes `x_B = B⁻¹(b − A_U u)` and runs the bounded-variable dual
//! simplex until primal feasibility returns — the same contract the dense
//! path honours.
//!
//! The dual iterations use the **bound-flipping ratio test** (Maros-style
//! BFRT): the dual objective is piecewise linear in the dual step, with one
//! breakpoint per eligible nonbasic column at `d_j / |ᾱ_j|`. Instead of
//! pivoting at the *first* breakpoint, the ratio test sorts the breakpoints
//! and walks them while the objective slope — the leaving row's primal
//! infeasibility, which shrinks by `u_j·|ᾱ_j|` at every crossed *boxed*
//! column — stays positive. Every boxed column crossed before the chosen
//! breakpoint flips to its opposite bound in **one batched `x_B` update**
//! (a single FTRAN of the accumulated `Σ A_j Δx_j`), and only then does the
//! entering column pivot. One dual pivot can thus absorb an rhs shift that
//! the classic one-flip-per-pivot test ([`RevisedSolver::set_long_step`]
//! keeps it around for ablations) would spend many pivots on. Leaving-row
//! selection mirrors the primal candidate-list machinery: a short list of
//! the most violated rows (scored `violation² / w_i` with the dual-devex
//! row weights) is re-checked first and a full row sweep runs only when the
//! list dries up.
//!
//! Per-solve counters — pivots, dual pivots, bound flips, refactorizations
//! — are exposed through [`SolveStats`] so the benches can attribute the
//! warm-path win per (pricing × factorization) cell.
//!
//! The primal devex weights are **bound-flip aware** and survive warm
//! repairs: the objective does not change across a warm re-solve, so the
//! reference framework is reset only when the objective does (phase
//! switches, cold solves). Dual pivots run the same pre-pivot weight
//! update as primal pivots, and every boxed column crossed by the BFRT has
//! its weight invalidated to the reference value at flip time — previously
//! flipped columns kept stale weights until the next framework reset.

use super::bounds::Csc;
use super::budget::{BudgetReason, SolveBudget};
use super::factor::{FactorKind, Factorization};
use super::problem::{LpProblem, Relation};
use super::simplex::{SimplexError, Solution};

const TOL: f64 = 1e-9;

/// Upper bound on the devex candidate-list length. Long enough that the
/// list survives several pivots between full sweeps, short enough that
/// re-pricing it is much cheaper than a sweep.
const CAND_MAX: usize = 48;

/// Devex reference-framework reset threshold: once any weight outgrows
/// this, the approximation has drifted too far from the reference frame —
/// restart with all weights at 1.
const DEVEX_RESET: f64 = 1e8;

/// Upper bound on the dual (leaving-row) candidate-list length. Shorter
/// than [`CAND_MAX`]: row violations drift faster than reduced costs, so a
/// long list would mostly hold stale rows.
const DUAL_CAND_MAX: usize = 32;

/// One breakpoint of the piecewise-linear dual objective in the
/// bound-flipping ratio test: nonbasic column `j` whose reduced cost hits
/// zero after a dual step of `ratio` along the leaving row.
#[derive(Clone, Copy)]
struct Breakpoint {
    ratio: f64,
    j: usize,
    /// `e_leave' B⁻¹ A_j` — the pivot element if `j` enters.
    alpha: f64,
    from_upper: bool,
}

/// Work counters for a solve, cumulative over a solver's lifetime (take a
/// snapshot before and [`SolveStats::since`] after to meter one re-solve).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SolveStats {
    /// Simplex basis changes, primal and dual, plus primal bound-flip
    /// steps — identical in meaning to [`super::simplex::Solution::iterations`].
    pub pivots: usize,
    /// Dual-simplex pivots alone — the §5.1 warm-repair work metric the
    /// long-step ratio test exists to cut.
    pub dual_pivots: usize,
    /// Nonbasic bound flips: primal ratio-test flips plus every boxed
    /// column batched by the dual BFRT.
    pub bound_flips: usize,
    /// Basis refactorizations (scheduled, drift-triggered, or after a
    /// rejected pivot update).
    pub refactorizations: usize,
}

impl SolveStats {
    /// Counters accumulated since the `earlier` snapshot.
    pub fn since(self, earlier: SolveStats) -> SolveStats {
        SolveStats {
            pivots: self.pivots.saturating_sub(earlier.pivots),
            dual_pivots: self.dual_pivots.saturating_sub(earlier.dual_pivots),
            bound_flips: self.bound_flips.saturating_sub(earlier.bound_flips),
            refactorizations: self.refactorizations.saturating_sub(earlier.refactorizations),
        }
    }
}

/// Column-pricing rule for the primal iterations (mirrored as the
/// leaving-row rule in the dual iterations).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Pricing {
    /// Full nonbasic sweep, most attractive reduced cost per pivot.
    Dantzig,
    /// Devex reference weights over a lazily refreshed candidate list —
    /// fewer pivots *and* cheaper pricing per pivot; the production rule.
    #[default]
    Devex,
}

/// Where a column currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum VarState {
    Basic,
    AtLower,
    AtUpper,
}

/// Bounded-variable revised simplex solver. Retains its final basis so
/// [`super::warm::WarmSolver`] can re-solve after rhs/bound updates.
pub struct RevisedSolver {
    n_orig: usize,
    ncols: usize,
    m: usize,
    /// first artificial column (== ncols when the problem needed none)
    art_base: usize,
    csc: Csc,
    /// phase-2 costs (structural entries only; slacks/artificials are 0)
    cost: Vec<f64>,
    /// per-column upper bound; lower bounds are all 0. Artificials are
    /// clamped to `[0, 0]` after phase 1, which blocks them permanently.
    upper: Vec<f64>,
    /// sign-normalized rhs (`b ≥ 0` at build time)
    b: Vec<f64>,
    /// sign applied to each original row at build time
    row_sign: Vec<f64>,
    basis: Vec<usize>,
    state: Vec<VarState>,
    xb: Vec<f64>,
    factor: Box<dyn Factorization>,
    /// the engine actually built (never [`FactorKind::Auto`])
    factor_kind: FactorKind,
    pricing: Pricing,
    /// devex reference weights per column (primal pricing)
    pweight: Vec<f64>,
    /// devex reference weights per row (dual leaving-row selection)
    dweight: Vec<f64>,
    /// candidate list for partial primal pricing
    cands: Vec<usize>,
    /// candidate list for dual leaving-row partial pricing
    dcands: Vec<usize>,
    pub(crate) iterations: usize,
    /// dual-simplex pivots (subset of `iterations`)
    dual_pivots: usize,
    /// nonbasic bound flips (primal flip steps + dual BFRT batch members)
    bound_flips: usize,
    /// basis refactorizations performed
    refactorizations: usize,
    /// long-step (bound-flipping) dual ratio test; `false` restores the
    /// classic one-flip-per-pivot test for ablations/differential tests
    long_step: bool,
    phase1_done: bool,
    /// per-solve resource caps ([`SolveBudget`]); unlimited by default
    budget: SolveBudget,
    /// `iterations` snapshot taken when the current solve armed its budget
    budget_base_pivots: usize,
    /// `refactorizations` snapshot at budget arming
    budget_base_refactors: usize,
    /// wall-clock deadline of the current solve (set only when the budget
    /// carries a wall cap — the unlimited path never reads the clock)
    budget_deadline: Option<std::time::Instant>,
    // scratch buffers reused across pivots
    w: Vec<f64>,
    y: Vec<f64>,
    rho: Vec<f64>,
    rhs_buf: Vec<f64>,
    flip_buf: Vec<f64>,
    cb_scratch: Vec<(usize, f64)>,
}

impl RevisedSolver {
    /// Build with the production configuration (devex pricing, automatic
    /// factorization choice).
    pub fn new(p: &LpProblem) -> Self {
        Self::with_config(p, Pricing::default(), FactorKind::default())
    }

    /// Build standard form: one slack per `≤`/`≥` row, one artificial per
    /// `≥`/`=` row, rows sign-flipped so `b ≥ 0`, initial basis = the
    /// identity of slacks/artificials. `pricing` and `factor` select the
    /// inner engines ([`FactorKind::Auto`] resolves against `m` here).
    pub fn with_config(p: &LpProblem, pricing: Pricing, factor: FactorKind) -> Self {
        let m = p.constraints.len();
        let n = p.num_vars;

        let mut n_slack = 0usize;
        let mut n_art = 0usize;
        for c in &p.constraints {
            let mut rel = c.rel;
            if c.rhs < 0.0 {
                rel = match rel {
                    Relation::Le => Relation::Ge,
                    Relation::Ge => Relation::Le,
                    Relation::Eq => Relation::Eq,
                };
            }
            match rel {
                Relation::Le => n_slack += 1,
                Relation::Ge => {
                    n_slack += 1;
                    n_art += 1;
                }
                Relation::Eq => n_art += 1,
            }
        }
        let art_base = n + n_slack;
        let ncols = art_base + n_art;

        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); ncols];
        let mut b = vec![0.0; m];
        let mut row_sign = vec![1.0; m];
        let mut basis = vec![usize::MAX; m];
        let mut next_slack = n;
        let mut next_art = art_base;

        for (i, c) in p.constraints.iter().enumerate() {
            let mut rel = c.rel;
            let mut rhs = c.rhs;
            let mut sign = 1.0;
            if rhs < 0.0 {
                sign = -1.0;
                rhs = -rhs;
                rel = match rel {
                    Relation::Le => Relation::Ge,
                    Relation::Ge => Relation::Le,
                    Relation::Eq => Relation::Eq,
                };
            }
            row_sign[i] = sign;
            b[i] = rhs;
            for &(v, co) in &c.terms {
                cols[v].push((i, sign * co));
            }
            match rel {
                Relation::Le => {
                    cols[next_slack].push((i, 1.0));
                    basis[i] = next_slack;
                    next_slack += 1;
                }
                Relation::Ge => {
                    cols[next_slack].push((i, -1.0));
                    next_slack += 1;
                    cols[next_art].push((i, 1.0));
                    basis[i] = next_art;
                    next_art += 1;
                }
                Relation::Eq => {
                    cols[next_art].push((i, 1.0));
                    basis[i] = next_art;
                    next_art += 1;
                }
            }
        }
        debug_assert_eq!(next_slack, art_base);
        debug_assert_eq!(next_art, ncols);

        let csc = Csc::from_columns(m, cols);

        let mut cost = vec![0.0; ncols];
        cost[..n].copy_from_slice(&p.objective);
        let mut upper = vec![f64::INFINITY; ncols];
        upper[..n].copy_from_slice(&p.upper);

        let mut state = vec![VarState::AtLower; ncols];
        let mut xb = vec![0.0; m];
        for (i, &bi) in basis.iter().enumerate() {
            state[bi] = VarState::Basic;
            xb[i] = b[i];
        }

        let factor_kind = factor.resolve(m);
        RevisedSolver {
            n_orig: n,
            ncols,
            m,
            art_base,
            csc,
            cost,
            upper,
            b,
            row_sign,
            basis,
            state,
            xb,
            factor: factor_kind.build(m),
            factor_kind,
            pricing,
            pweight: vec![1.0; ncols],
            dweight: vec![1.0; m],
            cands: Vec::new(),
            dcands: Vec::new(),
            iterations: 0,
            dual_pivots: 0,
            bound_flips: 0,
            refactorizations: 0,
            long_step: true,
            phase1_done: false,
            budget: SolveBudget::default(),
            budget_base_pivots: 0,
            budget_base_refactors: 0,
            budget_deadline: None,
            w: vec![0.0; m],
            y: vec![0.0; m],
            rho: vec![0.0; m],
            rhs_buf: vec![0.0; m],
            flip_buf: vec![0.0; m],
            cb_scratch: Vec::with_capacity(m),
        }
    }

    /// The pricing rule this solver was built with.
    pub fn pricing(&self) -> Pricing {
        self.pricing
    }

    /// The factorization engine actually in use (never [`FactorKind::Auto`]).
    pub fn factor_kind(&self) -> FactorKind {
        self.factor_kind
    }

    /// Cumulative work counters (pivots, dual pivots, bound flips,
    /// refactorizations) since construction. Snapshot before a re-solve and
    /// use [`SolveStats::since`] to meter that re-solve alone.
    pub fn stats(&self) -> SolveStats {
        SolveStats {
            pivots: self.iterations,
            dual_pivots: self.dual_pivots,
            bound_flips: self.bound_flips,
            refactorizations: self.refactorizations,
        }
    }

    /// Install a per-solve resource budget. Applies to every subsequent
    /// [`Self::solve`] / [`Self::warm_resolve`]; each arms the budget
    /// afresh at entry (caps meter one solve attempt, not the solver's
    /// lifetime). The default unlimited budget changes nothing and never
    /// reads the clock, keeping default-path results bit-identical.
    pub fn set_budget(&mut self, budget: SolveBudget) {
        self.budget = budget;
    }

    /// The budget in force for subsequent solves.
    pub fn budget(&self) -> SolveBudget {
        self.budget
    }

    /// Snapshot the work counters (and deadline, when a wall cap is set)
    /// so the caps meter the solve that is about to run.
    fn arm_budget(&mut self) {
        self.budget_base_pivots = self.iterations;
        self.budget_base_refactors = self.refactorizations;
        self.budget_deadline = self.budget.max_wall.map(|w| std::time::Instant::now() + w);
    }

    /// Enforce the armed budget; called at the top of every simplex
    /// iteration and before each refactorization. Pure counter compares on
    /// the deterministic caps; the clock is read only when a wall cap is
    /// actually set.
    fn check_budget(&self) -> Result<(), SimplexError> {
        if let Some(cap) = self.budget.max_pivots {
            if self.iterations - self.budget_base_pivots >= cap {
                return Err(SimplexError::BudgetExhausted(BudgetReason::Pivots));
            }
        }
        if let Some(cap) = self.budget.max_refactors {
            if self.refactorizations - self.budget_base_refactors >= cap {
                return Err(SimplexError::BudgetExhausted(BudgetReason::Refactors));
            }
        }
        if let Some(deadline) = self.budget_deadline {
            if std::time::Instant::now() >= deadline {
                return Err(SimplexError::BudgetExhausted(BudgetReason::WallClock));
            }
        }
        Ok(())
    }

    /// Toggle the long-step (bound-flipping) dual ratio test. On by
    /// default; switching it off restores the classic one-flip-per-pivot
    /// dual ratio test — kept so ablations and differential tests can pin
    /// the two paths to identical optima.
    pub fn set_long_step(&mut self, enabled: bool) {
        self.long_step = enabled;
    }

    /// Replace a row's rhs (original row order; sign normalization from
    /// build time is reapplied).
    pub fn update_rhs(&mut self, row: usize, rhs: f64) {
        self.b[row] = self.row_sign[row] * rhs;
    }

    /// Replace a structural variable's upper bound. A nonbasic variable
    /// resting on a bound that vanishes drops to its lower bound; a basic
    /// variable pushed out of range is repaired by the next dual solve.
    pub fn update_upper(&mut self, var: usize, ub: f64) {
        debug_assert!(var < self.n_orig);
        self.upper[var] = ub;
        if self.state[var] == VarState::AtUpper && !ub.is_finite() {
            self.state[var] = VarState::AtLower;
        }
    }

    /// Whether column `j` is pinned (`u_j ≤ 0`, so it can never move off 0).
    #[inline]
    fn fixed(&self, j: usize) -> bool {
        self.upper[j] <= 0.0
    }

    /// `x_B = B⁻¹ (b − Σ_{j at upper} u_j A_j)` — nonbasic-at-lower columns
    /// contribute nothing because every lower bound is 0.
    fn recompute_xb(&mut self) {
        self.rhs_buf.copy_from_slice(&self.b);
        for j in 0..self.ncols {
            if self.state[j] == VarState::AtUpper {
                let u = self.upper[j];
                if u > 0.0 && u.is_finite() {
                    let (rows, vals) = self.csc.col(j);
                    for (&i, &a) in rows.iter().zip(vals) {
                        self.rhs_buf[i] -= u * a;
                    }
                }
            }
        }
        let mut xb = std::mem::take(&mut self.xb);
        self.factor.ftran_dense(&self.rhs_buf, &mut xb);
        self.xb = xb;
    }

    /// `y = c_B' B⁻¹` for the given cost vector.
    fn compute_y(&mut self, cost: &[f64]) {
        self.cb_scratch.clear();
        for (k, &j) in self.basis.iter().enumerate() {
            let c = cost[j];
            if c != 0.0 {
                self.cb_scratch.push((k, c));
            }
        }
        let mut y = std::mem::take(&mut self.y);
        self.factor.btran_costs(&self.cb_scratch, &mut y);
        self.y = y;
    }

    /// FTRAN of column `j` into the scratch `w`.
    fn ftran_col(&mut self, j: usize) {
        let (rows, vals) = self.csc.col(j);
        let mut w = std::mem::take(&mut self.w);
        self.factor.ftran_sparse(rows, vals, &mut w);
        self.w = w;
    }

    /// `rho = e_r' B⁻¹` into the scratch `rho`.
    fn btran_row(&mut self, r: usize) {
        let mut rho = std::mem::take(&mut self.rho);
        self.factor.btran_unit(r, &mut rho);
        self.rho = rho;
    }

    /// Refactorize and refresh `x_B`; called on drift or when the engine
    /// says so (eta count for the dense inverse, fill growth for LU).
    fn refactor(&mut self) -> Result<(), SimplexError> {
        if let Some(cap) = self.budget.max_refactors {
            if self.refactorizations - self.budget_base_refactors >= cap {
                return Err(SimplexError::BudgetExhausted(BudgetReason::Refactors));
            }
        }
        self.factor
            .refactor(&self.csc, &self.basis)
            .map_err(|_| SimplexError::Numerical("singular basis on refactor"))?;
        self.refactorizations += 1;
        self.recompute_xb();
        Ok(())
    }

    /// Dual-devex row-weight update — O(m) from the entering column's
    /// FTRAN image alone, so (under devex pricing) it runs on *every*
    /// pivot, primal or dual, and the weights stay usable across the
    /// warm-start dual repairs. Dantzig-configured solves skip it: they
    /// never read the weights, and the baseline ablation cells must not
    /// carry devex bookkeeping inside the thing they isolate.
    fn update_dual_weights(&mut self, leave: usize) {
        let tau = self.w[leave];
        if tau.abs() < TOL {
            return; // degenerate pivot: keep the old (still valid) weights
        }
        let dr_old = self.dweight[leave].max(1.0);
        let tau2 = tau * tau;
        let mut maxw: f64 = 0.0;
        for i in 0..self.m {
            if i == leave {
                continue;
            }
            let wi = self.w[i];
            if wi != 0.0 {
                let cand = (wi * wi / tau2) * dr_old;
                if cand > self.dweight[i] {
                    self.dweight[i] = cand;
                }
            }
            maxw = maxw.max(self.dweight[i]);
        }
        self.dweight[leave] = (dr_old / tau2).max(1.0);
        if maxw > DEVEX_RESET {
            self.dweight.fill(1.0);
        }
    }

    /// Primal-devex weight update, run *before* the pivot is applied
    /// (needs `e_leave' B⁻¹` of the outgoing basis). Partial devex: only
    /// the candidate list — the columns that will actually be priced next —
    /// receives the exact `max(w_j, (α_rj/α_rq)²·w_q)` update; all other
    /// weights stay stale-but-monotone until the next reference reset.
    fn update_primal_weights(&mut self, enter: usize, leave: usize) {
        let alpha_q = self.w[leave];
        if alpha_q.abs() < TOL {
            return;
        }
        let wq = self.pweight[enter].max(1.0);
        let pivot2 = alpha_q * alpha_q;
        self.btran_row(leave);
        let mut maxw = wq;
        for &j in &self.cands {
            if j == enter || self.state[j] == VarState::Basic || self.fixed(j) {
                continue;
            }
            let alpha = self.csc.col_dot(j, &self.rho);
            if alpha != 0.0 {
                let cand = (alpha * alpha / pivot2) * wq;
                if cand > self.pweight[j] {
                    self.pweight[j] = cand;
                }
            }
            maxw = maxw.max(self.pweight[j]);
        }
        // the leaving variable re-enters the nonbasic pool carrying the
        // devex estimate of its new norm
        self.pweight[self.basis[leave]] = (wq / pivot2).max(1.0);
        if maxw > DEVEX_RESET {
            self.pweight.fill(1.0);
        }
    }

    /// Infeasibility-signed reduced cost of nonbasic column `j`: positive
    /// means moving `j` off its bound improves the objective.
    #[inline]
    fn attractiveness(&self, j: usize, cost: &[f64]) -> f64 {
        let d = cost[j] - self.csc.col_dot(j, &self.y);
        match self.state[j] {
            VarState::AtLower => -d,
            VarState::AtUpper => d,
            VarState::Basic => 0.0,
        }
    }

    /// Dantzig pricing: full sweep, most attractive reduced cost. With
    /// `bland`, first attractive index (Bland's anti-cycling rule).
    fn price_dantzig(&mut self, cost: &[f64], bland: bool) -> Option<(usize, bool)> {
        let mut enter = usize::MAX;
        let mut best = TOL;
        for j in 0..self.ncols {
            if self.state[j] == VarState::Basic || self.fixed(j) {
                continue;
            }
            let score = self.attractiveness(j, cost);
            if score > best {
                enter = j;
                best = score;
                if bland {
                    break;
                }
            }
        }
        if enter == usize::MAX {
            None
        } else {
            Some((enter, self.state[enter] == VarState::AtUpper))
        }
    }

    /// Re-price the candidate list, dropping entries that went basic,
    /// fixed, or unattractive. Returns the best by devex score.
    fn best_of_candidates(&mut self, cost: &[f64]) -> Option<(usize, bool)> {
        let mut enter = usize::MAX;
        let mut best_score = 0.0;
        let mut i = 0;
        while i < self.cands.len() {
            let j = self.cands[i];
            let mut drop = true;
            if self.state[j] != VarState::Basic && !self.fixed(j) {
                let a = self.attractiveness(j, cost);
                if a > TOL {
                    drop = false;
                    let score = a * a / self.pweight[j].max(1.0);
                    if score > best_score {
                        best_score = score;
                        enter = j;
                    }
                }
            }
            if drop {
                self.cands.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if enter == usize::MAX {
            None
        } else {
            Some((enter, self.state[enter] == VarState::AtUpper))
        }
    }

    /// Full pricing sweep: keep the [`CAND_MAX`] best-scoring attractive
    /// columns as the new candidate list.
    fn rebuild_candidates(&mut self, cost: &[f64]) {
        self.cands.clear();
        let mut scored: Vec<(f64, usize)> = Vec::new();
        for j in 0..self.ncols {
            if self.state[j] == VarState::Basic || self.fixed(j) {
                continue;
            }
            let a = self.attractiveness(j, cost);
            if a > TOL {
                scored.push((a * a / self.pweight[j].max(1.0), j));
            }
        }
        // descending score, index as a deterministic tie-break
        scored.sort_unstable_by(|x, y| y.0.partial_cmp(&x.0).unwrap().then(x.1.cmp(&y.1)));
        scored.truncate(CAND_MAX);
        self.cands.extend(scored.into_iter().map(|(_, j)| j));
    }

    /// Devex pricing: candidate list first, full-sweep refresh only when
    /// the list runs dry. `None` means no attractive column — optimal.
    fn price_devex(&mut self, cost: &[f64]) -> Option<(usize, bool)> {
        if let Some(pick) = self.best_of_candidates(cost) {
            return Some(pick);
        }
        self.rebuild_candidates(cost);
        self.best_of_candidates(cost)
    }

    /// Execute an accepted pivot: entering column `enter` moves by `t` from
    /// the bound it rests on, row `leave` leaves to its lower/upper bound.
    /// `self.w` must hold FTRAN(enter).
    fn apply_pivot(
        &mut self,
        enter: usize,
        enter_from_upper: bool,
        leave: usize,
        leave_to_upper: bool,
        t: f64,
    ) -> Result<(), SimplexError> {
        let sigma = if enter_from_upper { -1.0 } else { 1.0 };
        for i in 0..self.m {
            self.xb[i] -= sigma * t * self.w[i];
        }
        let entering_val = if enter_from_upper { self.upper[enter] - t } else { t };
        if self.pricing == Pricing::Devex {
            self.update_dual_weights(leave);
        }
        let old = self.basis[leave];
        self.state[old] = if leave_to_upper { VarState::AtUpper } else { VarState::AtLower };
        self.basis[leave] = enter;
        self.state[enter] = VarState::Basic;
        self.xb[leave] = entering_val;
        let (rows, vals) = self.csc.col(enter);
        if self.factor.pivot_update(rows, vals, &self.w, leave).is_err() {
            // pivot numerically unusable for the engine: rebuild instead
            self.refactor()?;
        }
        self.iterations += 1;
        Ok(())
    }

    /// Primal simplex to optimality for `cost` (devex or Dantzig pricing
    /// with a Bland fallback for anti-cycling). `reset_devex` restarts the
    /// devex reference framework and candidate list — required whenever the
    /// objective changed since the last primal pass (phase switch, cold
    /// solve). The warm path passes `false`: the objective is unchanged
    /// across a warm repair, the dual iterations keep the weights live
    /// (see [`Self::dual_iterate`]), and the cleanup pass prices better
    /// with them than from a cold reference frame.
    fn primal_iterate(&mut self, cost: &[f64], reset_devex: bool) -> Result<(), SimplexError> {
        let limit = 200 * (self.m + self.ncols) + 1000;
        let mut steps = 0usize;
        if reset_devex {
            // a (possibly) new objective invalidates the devex state: start
            // from a fresh reference framework and an empty candidate list
            self.pweight.fill(1.0);
            self.cands.clear();
        }
        loop {
            steps += 1;
            if steps > limit {
                return Err(SimplexError::IterLimit(limit));
            }
            self.check_budget()?;
            if self.factor.due_for_refactor() {
                self.refactor()?;
            }
            let use_bland = steps > 2 * (self.m + self.ncols);
            self.compute_y(cost);
            // ---- pricing ----
            let picked = if use_bland || self.pricing == Pricing::Dantzig {
                self.price_dantzig(cost, use_bland)
            } else {
                self.price_devex(cost)
            };
            let Some((enter, enter_from_upper)) = picked else {
                return Ok(()); // optimal
            };
            self.ftran_col(enter);
            let sigma = if enter_from_upper { -1.0 } else { 1.0 };
            // ---- bounded ratio test ----
            // the entering variable can at most traverse its own range
            let mut t_best = self.upper[enter];
            let mut leave = usize::MAX;
            let mut leave_to_upper = false;
            for i in 0..self.m {
                let delta = -sigma * self.w[i]; // d x_B[i] / dt
                if delta < -TOL {
                    let ratio = self.xb[i] / -delta; // hits lower bound 0
                    if ratio < t_best - TOL
                        || (ratio < t_best + TOL
                            && leave != usize::MAX
                            && self.basis[i] < self.basis[leave])
                    {
                        t_best = ratio;
                        leave = i;
                        leave_to_upper = false;
                    }
                } else if delta > TOL {
                    let ub = self.upper[self.basis[i]];
                    if ub.is_finite() {
                        let ratio = (ub - self.xb[i]) / delta; // hits upper
                        if ratio < t_best - TOL
                            || (ratio < t_best + TOL
                                && leave != usize::MAX
                                && self.basis[i] < self.basis[leave])
                        {
                            t_best = ratio;
                            leave = i;
                            leave_to_upper = true;
                        }
                    }
                }
            }
            if t_best.is_infinite() {
                return Err(SimplexError::Unbounded);
            }
            let t = t_best.max(0.0);
            if leave == usize::MAX {
                // bound flip: the entering variable crosses to its other
                // bound without any basis change — O(m) and pivot-free
                for i in 0..self.m {
                    self.xb[i] -= sigma * t * self.w[i];
                }
                self.state[enter] = if enter_from_upper {
                    VarState::AtLower
                } else {
                    VarState::AtUpper
                };
                self.iterations += 1;
                self.bound_flips += 1;
                continue;
            }
            if !use_bland && self.pricing == Pricing::Devex {
                self.update_primal_weights(enter, leave);
            }
            self.apply_pivot(enter, enter_from_upper, leave, leave_to_upper, t)?;
        }
    }

    /// Signed bound violation of basis row `i`: magnitude plus which bound
    /// is violated (`true` = above the upper bound).
    #[inline]
    fn row_violation(&self, i: usize) -> (f64, bool) {
        let ub = self.upper[self.basis[i]];
        let viol_low = -self.xb[i];
        let viol_up = if ub.is_finite() { self.xb[i] - ub } else { f64::NEG_INFINITY };
        if viol_up > viol_low {
            (viol_up, true)
        } else {
            (viol_low, false)
        }
    }

    /// Re-check the dual candidate list, dropping rows no longer violated;
    /// returns the best remaining row by devex score `violation² / w_i`.
    fn best_dual_candidate(&mut self) -> Option<(usize, f64, bool)> {
        let mut best = None;
        let mut best_score = 0.0;
        let mut k = 0;
        while k < self.dcands.len() {
            let i = self.dcands[k];
            let (viol, above) = self.row_violation(i);
            if viol <= TOL {
                self.dcands.swap_remove(k);
                continue;
            }
            let score = viol * viol / self.dweight[i].max(1.0);
            if score > best_score {
                best_score = score;
                best = Some((i, viol, above));
            }
            k += 1;
        }
        best
    }

    /// Full row sweep: keep the [`DUAL_CAND_MAX`] best-scoring violated
    /// rows as the new dual candidate list.
    fn rebuild_dual_candidates(&mut self) {
        self.dcands.clear();
        let mut scored: Vec<(f64, usize)> = Vec::new();
        for i in 0..self.m {
            let (viol, _) = self.row_violation(i);
            if viol > TOL {
                let score = viol * viol / self.dweight[i].max(1.0);
                scored.push((score, i));
            }
        }
        scored.sort_unstable_by(|x, y| y.0.partial_cmp(&x.0).unwrap().then(x.1.cmp(&y.1)));
        scored.truncate(DUAL_CAND_MAX);
        self.dcands.extend(scored.into_iter().map(|(_, i)| i));
    }

    /// Leaving-row selection: Dantzig keeps the full-sweep largest
    /// violation (the ablation baseline); devex re-checks a short candidate
    /// list of the most violated rows first and sweeps only when the list
    /// dries up — declaring primal feasibility requires an empty sweep, so
    /// the partial pass never affects correctness, only which row repairs
    /// first.
    fn pick_leaving(&mut self) -> Option<(usize, f64, bool)> {
        if self.pricing == Pricing::Dantzig {
            let mut best = None;
            let mut best_viol = 0.0;
            for i in 0..self.m {
                let (viol, above) = self.row_violation(i);
                if viol > TOL && viol > best_viol {
                    best_viol = viol;
                    best = Some((i, viol, above));
                }
            }
            return best;
        }
        if let Some(pick) = self.best_dual_candidate() {
            return Some(pick);
        }
        self.rebuild_dual_candidates();
        self.best_dual_candidate()
    }

    /// Bounded-variable dual simplex: restore `0 ≤ x_B ≤ u_B` while keeping
    /// dual feasibility. The warm-start repair path, with the long-step
    /// bound-flipping ratio test (see the module docs).
    pub(crate) fn dual_iterate(&mut self) -> Result<(), SimplexError> {
        let cost = self.cost.clone();
        let limit = 200 * (self.m + self.ncols) + 1000;
        let mut steps = 0usize;
        self.dcands.clear();
        let mut bps: Vec<Breakpoint> = Vec::new();
        loop {
            steps += 1;
            if steps > limit {
                return Err(SimplexError::IterLimit(limit));
            }
            self.check_budget()?;
            if self.factor.due_for_refactor() {
                self.refactor()?;
            }
            // ---- leaving row (candidate list under devex) ----
            let Some((leave, worst, above)) = self.pick_leaving() else {
                return Ok(()); // primal feasible again
            };
            self.compute_y(&cost);
            self.btran_row(leave);
            // `dir`: the sign x_B[leave] must move in (+1 = decrease needed
            // is encoded through the eligibility signs below)
            let dir = if above { 1.0 } else { -1.0 };
            // ---- breakpoints of the piecewise-linear dual objective ----
            bps.clear();
            for j in 0..self.ncols {
                if self.state[j] == VarState::Basic || self.fixed(j) {
                    continue;
                }
                let alpha = self.csc.col_dot(j, &self.rho);
                let abar = dir * alpha;
                match self.state[j] {
                    VarState::AtLower if abar > TOL => {
                        let d = (cost[j] - self.csc.col_dot(j, &self.y)).max(0.0);
                        bps.push(Breakpoint { ratio: d / abar, j, alpha, from_upper: false });
                    }
                    VarState::AtUpper if abar < -TOL => {
                        // d ≤ 0 at an upper bound, so ratio = d / ᾱ ≥ 0
                        let d = (cost[j] - self.csc.col_dot(j, &self.y)).min(0.0);
                        bps.push(Breakpoint { ratio: d / abar, j, alpha, from_upper: true });
                    }
                    _ => {}
                }
            }
            if bps.is_empty() {
                // dual unbounded ⇒ primal infeasible for this rhs/bounds
                return Err(SimplexError::Infeasible(worst));
            }
            // ---- ratio test: classic min-ratio, or the BFRT walk ----
            let mut chosen: Option<Breakpoint> = None;
            let mut flip_end = 0usize;
            if !self.long_step {
                // strict improvement only: within the tolerance band the
                // first (smallest) index wins, which is the deterministic
                // tie-break we want
                let mut best_ratio = f64::INFINITY;
                for bp in &bps {
                    if bp.ratio < best_ratio - TOL {
                        best_ratio = bp.ratio;
                        chosen = Some(*bp);
                    }
                }
            } else {
                bps.sort_unstable_by(|a, b| {
                    a.ratio.partial_cmp(&b.ratio).unwrap().then(a.j.cmp(&b.j))
                });
                // walk the sorted breakpoints while the dual-objective
                // slope — the leaving row's remaining infeasibility —
                // stays positive; every boxed column crossed flips
                let mut slope = worst;
                for (k, bp) in bps.iter().enumerate() {
                    let u = self.upper[bp.j];
                    let flip_cost =
                        if u.is_finite() { u * (dir * bp.alpha).abs() } else { f64::INFINITY };
                    if slope - flip_cost <= TOL {
                        chosen = Some(*bp);
                        flip_end = k;
                        break;
                    }
                    slope -= flip_cost;
                }
            }
            let Some(bp) = chosen else {
                // slope stayed positive past every breakpoint: the dual
                // objective increases without bound ⇒ primal infeasible
                return Err(SimplexError::Infeasible(worst));
            };
            // ---- batched bound flips for the crossed breakpoints ----
            if flip_end > 0 {
                self.rhs_buf.fill(0.0);
                for fb in &bps[..flip_end] {
                    let u = self.upper[fb.j];
                    let dx = if fb.from_upper { -u } else { u };
                    let (rows, vals) = self.csc.col(fb.j);
                    for (&i, &a) in rows.iter().zip(vals) {
                        self.rhs_buf[i] += a * dx;
                    }
                    self.state[fb.j] =
                        if fb.from_upper { VarState::AtLower } else { VarState::AtUpper };
                    self.bound_flips += 1;
                    if self.pricing == Pricing::Devex {
                        // bound-flip-aware devex maintenance: the crossed
                        // column changes sides without a basis change, and
                        // its weight may date from an older reference
                        // frame. Invalidate it to the reference value so
                        // the post-repair primal cleanup (which now keeps
                        // weights across the warm path) never prices it
                        // with a stale norm estimate.
                        self.pweight[fb.j] = 1.0;
                    }
                }
                // one FTRAN absorbs every flip: x_B -= B⁻¹ (Σ A_j Δx_j)
                let mut flip = std::mem::take(&mut self.flip_buf);
                self.factor.ftran_dense(&self.rhs_buf, &mut flip);
                for i in 0..self.m {
                    self.xb[i] -= flip[i];
                }
                self.flip_buf = flip;
            }
            // step length: x_B[leave] lands exactly on its violated bound
            // (the flips above already moved it partway there)
            let target = if above { self.upper[self.basis[leave]] } else { 0.0 };
            let t = if bp.from_upper {
                (target - self.xb[leave]) / bp.alpha
            } else {
                (self.xb[leave] - target) / bp.alpha
            };
            let t = t.max(0.0);
            self.ftran_col(bp.j);
            if self.pricing == Pricing::Devex {
                // keep the primal weights live through the dual repair —
                // the same pre-pivot update a primal step runs, driven by
                // FTRAN(entering) already in `w` — so the warm path's
                // primal cleanup can reuse them instead of resetting the
                // reference framework every re-solve
                self.update_primal_weights(bp.j, leave);
            }
            self.apply_pivot(bp.j, bp.from_upper, leave, above, t)?;
            self.dual_pivots += 1;
        }
    }

    /// Drive basic artificials out of the basis after phase 1 (degenerate
    /// pivots); rows whose artificial cannot leave are redundant and the
    /// artificial stays basic pinned at 0 by its `[0,0]` bounds.
    fn expel_artificials(&mut self) -> Result<(), SimplexError> {
        for r in 0..self.m {
            if self.basis[r] < self.art_base {
                continue;
            }
            self.btran_row(r);
            let mut found = usize::MAX;
            for j in 0..self.art_base {
                // prefer columns free to move later (skip pinned ones)
                if self.state[j] == VarState::Basic || self.fixed(j) {
                    continue;
                }
                if self.csc.col_dot(j, &self.rho).abs() > 1e-7 {
                    found = j;
                    break;
                }
            }
            if found == usize::MAX {
                continue; // redundant row
            }
            let from_upper = self.state[found] == VarState::AtUpper;
            self.ftran_col(found);
            // xb[r] ≈ 0 after a successful phase 1, so this is a degenerate
            // (t = 0) basis change
            self.apply_pivot(found, from_upper, r, false, 0.0)?;
        }
        Ok(())
    }

    /// Two-phase solve from the current (initial) basis. The installed
    /// [`SolveBudget`] (if any) meters this call as one attempt.
    pub fn solve(&mut self) -> Result<Solution, SimplexError> {
        self.arm_budget();
        if !self.phase1_done {
            let any_artificial_basic = self.basis.iter().any(|&j| j >= self.art_base);
            if any_artificial_basic {
                let p1_cost: Vec<f64> = (0..self.ncols)
                    .map(|j| if j >= self.art_base { 1.0 } else { 0.0 })
                    .collect();
                self.primal_iterate(&p1_cost, true)?;
                let infeas: f64 = (0..self.m)
                    .filter(|&i| self.basis[i] >= self.art_base)
                    .map(|i| self.xb[i].max(0.0))
                    .sum();
                if infeas > 1e-7 {
                    return Err(SimplexError::Infeasible(infeas));
                }
                // block artificials permanently and snap stragglers to 0
                for j in self.art_base..self.ncols {
                    self.upper[j] = 0.0;
                    if self.state[j] == VarState::AtUpper {
                        self.state[j] = VarState::AtLower;
                    }
                }
                for i in 0..self.m {
                    if self.basis[i] >= self.art_base {
                        self.xb[i] = 0.0;
                    }
                }
                self.expel_artificials()?;
            }
            self.phase1_done = true;
        }
        let cost = self.cost.clone();
        self.primal_iterate(&cost, true)?;
        Ok(self.extract())
    }

    /// Warm re-solve after [`Self::update_rhs`] / [`Self::update_upper`]
    /// edits: refresh `x_B` against the stored basis, dual-simplex the bound
    /// violations away, then run a primal cleanup pass. The cleanup matters
    /// because *bound* edits can silently break dual feasibility even
    /// though reduced costs only depend on the basis: un-fixing a variable
    /// whose `u = 0` previously excluded it from pricing (its reduced cost
    /// carries no sign guarantee), or dropping an upper bound to infinity
    /// (the variable falls to its lower bound where `d ≥ 0` is required).
    /// The primal pass prices every column once and exits immediately when
    /// the dual repair already reached the optimum — the common case.
    /// Requires a completed prior [`Self::solve`].
    pub fn warm_resolve(&mut self) -> Result<Solution, SimplexError> {
        debug_assert!(self.phase1_done, "warm_resolve before any cold solve");
        self.arm_budget();
        self.recompute_xb();
        self.dual_iterate()?;
        let cost = self.cost.clone();
        // the objective is unchanged across a warm repair, so the devex
        // reference framework survives: weights were maintained through the
        // dual pivots and invalidated for BFRT-flipped columns
        self.primal_iterate(&cost, false)?;
        Ok(self.extract())
    }

    /// Current solution restricted to the structural variables.
    ///
    /// Relies on `self.y` holding `c_B' B⁻¹` for the phase-2 costs of the
    /// final basis — guaranteed because both [`Self::solve`] and
    /// [`Self::warm_resolve`] end in a [`Self::primal_iterate`] pass whose
    /// optimality exit prices against a freshly computed `y`.
    pub(crate) fn extract(&self) -> Solution {
        let mut x = vec![0.0; self.n_orig];
        for j in 0..self.n_orig {
            if self.state[j] == VarState::AtUpper {
                let u = self.upper[j];
                if u.is_finite() {
                    x[j] = u;
                }
            }
        }
        for i in 0..self.m {
            let bj = self.basis[i];
            if bj < self.n_orig {
                x[bj] = self.xb[i].max(0.0);
            }
        }
        let objective = self.cost[..self.n_orig].iter().zip(&x).map(|(c, v)| c * v).sum();
        // duals in original row order: undo the build-time sign flip
        let duals = (0..self.m).map(|i| self.row_sign[i] * self.y[i]).collect();
        Solution { x, objective, iterations: self.iterations, duals }
    }
}

/// One-shot convenience: build + solve with the revised simplex in its
/// production configuration.
pub fn solve(p: &LpProblem) -> Result<Solution, SimplexError> {
    RevisedSolver::new(p).solve()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::Relation::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    /// Every (pricing × factorization) configuration worth differentiating.
    fn all_configs() -> [(Pricing, FactorKind); 4] {
        [
            (Pricing::Dantzig, FactorKind::DenseInverse),
            (Pricing::Dantzig, FactorKind::SparseLu),
            (Pricing::Devex, FactorKind::DenseInverse),
            (Pricing::Devex, FactorKind::SparseLu),
        ]
    }

    fn solve_with(
        p: &LpProblem,
        pricing: Pricing,
        factor: FactorKind,
    ) -> Result<Solution, SimplexError> {
        RevisedSolver::with_config(p, pricing, factor).solve()
    }

    #[test]
    fn trivial_bounded_min() {
        // min -x0 s.t. x0 <= 4 (as a row) -> x0 = 4
        let mut p = LpProblem::new(1);
        p.set_objective(0, -1.0);
        p.add(vec![(0, 1.0)], Le, 4.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 4.0);
        assert_close(s.objective, -4.0);
    }

    #[test]
    fn variable_bound_replaces_row() {
        // same optimum expressed as a variable bound, zero constraint rows
        // beyond a dummy (m = 0 LPs are legal but trivial): bound-tight optimum
        let mut p = LpProblem::new(2);
        p.set_objective(0, -1.0);
        p.set_objective(1, -1.0);
        p.set_upper(0, 4.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Le, 6.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, -6.0);
        assert!(p.is_feasible(&s.x, 1e-7));
    }

    #[test]
    fn classic_two_var_all_configs() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> (2,6), 36
        let mut p = LpProblem::new(2);
        p.set_objective(0, -3.0);
        p.set_objective(1, -5.0);
        p.add(vec![(0, 1.0)], Le, 4.0);
        p.add(vec![(1, 2.0)], Le, 12.0);
        p.add(vec![(0, 3.0), (1, 2.0)], Le, 18.0);
        for (pricing, factor) in all_configs() {
            let s = solve_with(&p, pricing, factor).unwrap();
            assert_close(s.x[0], 2.0);
            assert_close(s.x[1], 6.0);
            assert_close(s.objective, -36.0);
        }
    }

    #[test]
    fn classic_two_var_with_bounds_instead_of_rows() {
        // x<=4 and y<=6 as bounds; 3x+2y<=18 stays a row
        let mut p = LpProblem::new(2);
        p.set_objective(0, -3.0);
        p.set_objective(1, -5.0);
        p.set_upper(0, 4.0);
        p.set_upper(1, 6.0);
        p.add(vec![(0, 3.0), (1, 2.0)], Le, 18.0);
        for (pricing, factor) in all_configs() {
            let s = solve_with(&p, pricing, factor).unwrap();
            assert_close(s.x[0], 2.0);
            assert_close(s.x[1], 6.0);
            assert_close(s.objective, -36.0);
        }
    }

    #[test]
    fn equality_constraints() {
        // min x+2y s.t. x + y = 10, x - y = 2 -> x=6, y=4, obj 14
        let mut p = LpProblem::new(2);
        p.set_objective(0, 1.0);
        p.set_objective(1, 2.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, 10.0);
        p.add(vec![(0, 1.0), (1, -1.0)], Eq, 2.0);
        for (pricing, factor) in all_configs() {
            let s = solve_with(&p, pricing, factor).unwrap();
            assert_close(s.x[0], 6.0);
            assert_close(s.x[1], 4.0);
            assert_close(s.objective, 14.0);
        }
    }

    #[test]
    fn ge_constraints_and_negative_rhs() {
        let mut p = LpProblem::new(1);
        p.set_objective(0, 1.0);
        p.add(vec![(0, 1.0)], Ge, 3.0);
        p.add(vec![(0, -1.0)], Le, -3.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 3.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new(1);
        p.add(vec![(0, 1.0)], Le, 1.0);
        p.add(vec![(0, 1.0)], Ge, 2.0);
        for (pricing, factor) in all_configs() {
            assert!(matches!(
                solve_with(&p, pricing, factor),
                Err(SimplexError::Infeasible(_))
            ));
        }
    }

    #[test]
    fn bound_makes_row_infeasible() {
        // x >= 2 but x <= 1 via bound
        let mut p = LpProblem::new(1);
        p.set_upper(0, 1.0);
        p.add(vec![(0, 1.0)], Ge, 2.0);
        assert!(matches!(solve(&p), Err(SimplexError::Infeasible(_))));
    }

    #[test]
    fn unbounded_detected() {
        let mut p = LpProblem::new(1);
        p.set_objective(0, -1.0);
        p.add(vec![(0, -1.0)], Le, 0.0);
        for (pricing, factor) in all_configs() {
            assert_eq!(solve_with(&p, pricing, factor).unwrap_err(), SimplexError::Unbounded);
        }
    }

    #[test]
    fn bound_tames_unbounded_direction() {
        // same ray, but a variable bound caps it
        let mut p = LpProblem::new(1);
        p.set_objective(0, -1.0);
        p.set_upper(0, 7.5);
        p.add(vec![(0, -1.0)], Le, 0.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 7.5);
        assert_close(s.objective, -7.5);
    }

    #[test]
    fn degenerate_zero_bound_fixes_variable() {
        // u = 0 pins x0; optimum must route through x1
        let mut p = LpProblem::new(2);
        p.set_objective(0, -5.0);
        p.set_objective(1, -1.0);
        p.set_upper(0, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Le, 3.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 0.0);
        assert_close(s.x[1], 3.0);
        assert_close(s.objective, -3.0);
    }

    #[test]
    fn minimax_structure_like_lpp1() {
        let mut p = LpProblem::new(5);
        p.set_objective(4, 1.0);
        p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, 10.0);
        p.add(vec![(2, 1.0), (3, 1.0)], Eq, 2.0);
        for (pricing, factor) in all_configs() {
            let s = solve_with(&p, pricing, factor).unwrap();
            assert_close(s.objective, 6.0);
            assert!(p.is_feasible(&s.x, 1e-7));
        }
    }

    #[test]
    fn warm_resolve_tracks_rhs_changes() {
        let build = |l0: f64, l1: f64| {
            let mut p = LpProblem::new(5);
            p.set_objective(4, 1.0);
            p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
            p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
            p.add(vec![(0, 1.0), (1, 1.0)], Eq, l0);
            p.add(vec![(2, 1.0), (3, 1.0)], Eq, l1);
            p
        };
        for (pricing, factor) in all_configs() {
            let mut s = RevisedSolver::with_config(&build(10.0, 2.0), pricing, factor);
            let s0 = s.solve().unwrap();
            assert_close(s0.objective, 6.0);
            for (l0, l1) in [(4.0, 4.0), (20.0, 0.0), (1.0, 7.0), (100.0, 50.0)] {
                s.update_rhs(2, l0);
                s.update_rhs(3, l1);
                let sw = s.warm_resolve().unwrap();
                let sc = solve(&build(l0, l1)).unwrap();
                assert!(
                    (sw.objective - sc.objective).abs() < 1e-6,
                    "{pricing:?}/{factor:?} loads ({l0},{l1}): warm {} cold {}",
                    sw.objective,
                    sc.objective
                );
            }
        }
    }

    #[test]
    fn warm_resolve_tracks_bound_changes() {
        // min -x0-x1 s.t. x0+x1 <= 10, x0 <= u (bound, updated warm)
        for (pricing, factor) in all_configs() {
            let mut p = LpProblem::new(2);
            p.set_objective(0, -2.0);
            p.set_objective(1, -1.0);
            p.set_upper(0, 3.0);
            p.add(vec![(0, 1.0), (1, 1.0)], Le, 10.0);
            let mut s = RevisedSolver::with_config(&p, pricing, factor);
            let s0 = s.solve().unwrap();
            assert_close(s0.objective, -13.0); // x0=3, x1=7
            for u in [0.0, 5.0, 8.0, 2.0, 10.0, 12.0] {
                s.update_upper(0, u);
                let sw = s.warm_resolve().unwrap();
                let expect = -(u.min(10.0) * 2.0 + (10.0 - u.min(10.0)));
                assert!(
                    (sw.objective - expect).abs() < 1e-6,
                    "{pricing:?}/{factor:?} u={u}: warm {} expect {expect}",
                    sw.objective
                );
            }
        }
    }

    #[test]
    fn solution_is_feasible_random_problems() {
        use crate::rng::Rng;
        for (pricing, factor) in all_configs() {
            let mut rng = Rng::new(123);
            for case in 0..60 {
                let n = 2 + (case % 4);
                let m = 1 + (case % 5);
                let mut p = LpProblem::new(n);
                for j in 0..n {
                    p.set_objective(j, rng.f64() * 2.0 - 0.5);
                }
                // sprinkle finite bounds on some variables
                for j in 0..n {
                    if rng.f64() < 0.4 {
                        p.set_upper(j, rng.f64() * 3.0);
                    }
                }
                for _ in 0..m {
                    let terms: Vec<(usize, f64)> = (0..n).map(|j| (j, rng.f64())).collect();
                    p.add(terms, Le, 1.0 + rng.f64() * 5.0);
                }
                match solve_with(&p, pricing, factor) {
                    Ok(s) => {
                        assert!(
                            p.is_feasible(&s.x, 1e-6),
                            "{pricing:?}/{factor:?} case {case}: {:?}",
                            s.x
                        );
                        for _ in 0..20 {
                            let cand: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0).collect();
                            if p.is_feasible(&cand, 0.0) {
                                assert!(
                                    s.objective <= p.objective_at(&cand) + 1e-6,
                                    "{pricing:?}/{factor:?} case {case}: {} > {}",
                                    s.objective,
                                    p.objective_at(&cand)
                                );
                            }
                        }
                    }
                    Err(SimplexError::Unbounded) => {}
                    Err(e) => panic!("{pricing:?}/{factor:?} case {case}: {e}"),
                }
            }
        }
    }

    /// Devex must reach the same optima as Dantzig while its candidate
    /// list keeps full pricing sweeps rare (indirectly: it must not blow
    /// the pivot budget on a mid-sized minimax instance).
    #[test]
    fn devex_and_dantzig_agree_on_minimax_family() {
        use crate::rng::Rng;
        let mut rng = Rng::new(31);
        for trial in 0..15 {
            let g = 4 + (trial % 4); // gpus
            let e = 2 * g; // experts, 2 replicas each
            let nv = 2 * e + 1;
            let t = nv - 1;
            let mut p = LpProblem::new(nv);
            p.set_objective(t, 1.0);
            let homes: Vec<[usize; 2]> = (0..e)
                .map(|_| {
                    let a = rng.below(g as u64) as usize;
                    let b = (a + 1 + rng.below((g - 1) as u64) as usize) % g;
                    [a, b]
                })
                .collect();
            for gi in 0..g {
                let mut terms = vec![(t, -1.0)];
                for (ei, h) in homes.iter().enumerate() {
                    for (r, &hh) in h.iter().enumerate() {
                        if hh == gi {
                            terms.push((ei * 2 + r, 1.0));
                        }
                    }
                }
                p.add(terms, Relation::Le, 0.0);
            }
            for ei in 0..e {
                p.add(vec![(ei * 2, 1.0), (ei * 2 + 1, 1.0)], Relation::Eq, rng.below(200) as f64);
            }
            let sx = solve_with(&p, Pricing::Dantzig, FactorKind::DenseInverse).unwrap();
            for factor in [FactorKind::DenseInverse, FactorKind::SparseLu] {
                let sd = solve_with(&p, Pricing::Devex, factor).unwrap();
                assert!(
                    (sd.objective - sx.objective).abs() < 1e-6 * (1.0 + sx.objective.abs()),
                    "trial {trial} {factor:?}: devex {} dantzig {}",
                    sd.objective,
                    sx.objective
                );
            }
        }
    }

    /// Beale's classic cycling LP: every early vertex is degenerate, so
    /// pivots are spent without objective progress and (without the Bland
    /// fallback) Dantzig pricing cycles forever. The hard pivot cap must
    /// surface as a typed `BudgetExhausted`, never a hang.
    fn beale_degenerate() -> LpProblem {
        let mut p = LpProblem::new(4);
        p.set_objective(0, -0.75);
        p.set_objective(1, 150.0);
        p.set_objective(2, -0.02);
        p.set_objective(3, 6.0);
        p.add(vec![(0, 0.25), (1, -60.0), (2, -0.04), (3, 9.0)], Le, 0.0);
        p.add(vec![(0, 0.5), (1, -90.0), (2, -0.02), (3, 3.0)], Le, 0.0);
        p.add(vec![(2, 1.0)], Le, 1.0);
        p
    }

    #[test]
    fn pivot_cap_trips_on_degenerate_instance() {
        use crate::lp::budget::{BudgetReason, SolveBudget};
        let p = beale_degenerate();
        for (pricing, factor) in all_configs() {
            // unlimited: reaches the known optimum −0.05 at (1/25, 0, 1, 0)
            let mut full = RevisedSolver::with_config(&p, pricing, factor);
            let sol = full.solve().unwrap();
            assert_close(sol.objective, -0.05);
            assert!(full.stats().pivots >= 2, "{pricing:?}/{factor:?}");
            // capped below what the solve needs: typed exhaustion, and the
            // cap is respected exactly (no overshoot past the budget)
            let mut capped = RevisedSolver::with_config(&p, pricing, factor);
            capped.set_budget(SolveBudget::with_max_pivots(1));
            assert_eq!(
                capped.solve().unwrap_err(),
                SimplexError::BudgetExhausted(BudgetReason::Pivots),
                "{pricing:?}/{factor:?}"
            );
            assert!(capped.stats().pivots <= 1, "{pricing:?}/{factor:?}");
        }
    }

    #[test]
    fn zero_pivot_budget_starves_before_any_work() {
        use crate::lp::budget::{BudgetReason, SolveBudget};
        let p = beale_degenerate();
        let mut s = RevisedSolver::new(&p);
        s.set_budget(SolveBudget::with_max_pivots(0));
        assert_eq!(
            s.solve().unwrap_err(),
            SimplexError::BudgetExhausted(BudgetReason::Pivots)
        );
        assert_eq!(s.stats().pivots, 0);
    }

    #[test]
    fn zero_wall_budget_trips_on_the_clock() {
        use crate::lp::budget::{BudgetReason, SolveBudget};
        let p = beale_degenerate();
        let mut s = RevisedSolver::new(&p);
        s.set_budget(SolveBudget {
            max_wall: Some(std::time::Duration::ZERO),
            ..SolveBudget::default()
        });
        assert_eq!(
            s.solve().unwrap_err(),
            SimplexError::BudgetExhausted(BudgetReason::WallClock)
        );
    }

    #[test]
    fn generous_budget_is_bit_identical_to_unlimited() {
        use crate::lp::budget::SolveBudget;
        let p = beale_degenerate();
        for (pricing, factor) in all_configs() {
            let mut free = RevisedSolver::with_config(&p, pricing, factor);
            let a = free.solve().unwrap();
            let mut capped = RevisedSolver::with_config(&p, pricing, factor);
            capped.set_budget(SolveBudget::with_max_pivots(1_000_000));
            let b = capped.solve().unwrap();
            // budget checks are pure counter compares: they must not
            // perturb a single pricing or ratio-test decision
            assert_eq!(a.x, b.x, "{pricing:?}/{factor:?}");
            assert_eq!(a.iterations, b.iterations, "{pricing:?}/{factor:?}");
            assert_eq!(free.stats(), capped.stats(), "{pricing:?}/{factor:?}");
        }
    }

    #[test]
    fn budget_rearms_per_solve_across_warm_repairs() {
        use crate::lp::budget::{BudgetReason, SolveBudget};
        // the cap meters each attempt, not the solver lifetime: a sequence
        // of warm repairs under a per-solve cap keeps succeeding, and a
        // starved warm repair reports exhaustion instead of looping
        let build = |l0: f64, l1: f64| {
            let mut p = LpProblem::new(5);
            p.set_objective(4, 1.0);
            p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
            p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
            p.add(vec![(0, 1.0), (1, 1.0)], Eq, l0);
            p.add(vec![(2, 1.0), (3, 1.0)], Eq, l1);
            p
        };
        let mut s = RevisedSolver::new(&build(10.0, 2.0));
        s.set_budget(SolveBudget::with_max_pivots(10_000));
        s.solve().unwrap();
        for (l0, l1) in [(4.0, 4.0), (20.0, 0.0), (1.0, 7.0)] {
            s.update_rhs(2, l0);
            s.update_rhs(3, l1);
            let sw = s.warm_resolve().unwrap();
            let sc = solve(&build(l0, l1)).unwrap();
            assert!((sw.objective - sc.objective).abs() < 1e-6);
        }
        s.set_budget(SolveBudget::with_max_pivots(0));
        s.update_rhs(2, 50.0);
        assert_eq!(
            s.warm_resolve().unwrap_err(),
            SimplexError::BudgetExhausted(BudgetReason::Pivots)
        );
    }
}
