//! Warm-started LP re-solve (the paper's §5.1 optimization).
//!
//! Across micro-batches the LPP-1 constraint *matrix* is fixed by the expert
//! placement; only the rhs (`load_e`, and trivially the `≤ t` rows' zeros)
//! changes. The optimal basis of micro-batch *k* therefore stays
//! dual-feasible for micro-batch *k+1*, and a handful of dual-simplex pivots
//! restore primal feasibility — orders of magnitude cheaper than a cold
//! two-phase solve (measured in Fig. 11's "warm solving" ablation).

use super::problem::LpProblem;
use super::simplex::{SimplexError, Solution, Solver};

/// A solver that remembers its optimal basis between solves.
pub struct WarmSolver {
    solver: Option<Solver>,
    problem: LpProblem,
    /// Pivots spent on the most recent solve (cold or warm).
    pub last_iterations: usize,
    /// Whether the most recent solve used the warm path.
    pub last_was_warm: bool,
}

impl WarmSolver {
    pub fn new(problem: LpProblem) -> Self {
        WarmSolver { solver: None, problem, last_iterations: 0, last_was_warm: false }
    }

    pub fn problem(&self) -> &LpProblem {
        &self.problem
    }

    /// Solve from scratch (two-phase primal).
    pub fn solve_cold(&mut self) -> Result<Solution, SimplexError> {
        let mut s = Solver::new(&self.problem);
        let sol = s.solve()?;
        self.last_iterations = s.iterations;
        self.last_was_warm = false;
        self.solver = Some(s);
        Ok(sol)
    }

    /// Apply rhs updates then solve, warm when allowed and possible.
    pub fn solve_with(
        &mut self,
        updates: &[(usize, f64)],
        use_warm: bool,
    ) -> Result<Solution, SimplexError> {
        if use_warm {
            self.resolve(updates)
        } else {
            for &(row, rhs) in updates {
                self.problem.set_rhs(row, rhs);
            }
            self.solve_cold()
        }
    }

    /// Re-solve after changing some rhs values. `updates` are
    /// (constraint row index, new rhs) pairs in the original row order.
    /// Falls back to a cold solve if no prior basis exists or the dual
    /// simplex stalls.
    pub fn resolve(&mut self, updates: &[(usize, f64)]) -> Result<Solution, SimplexError> {
        for &(row, rhs) in updates {
            self.problem.set_rhs(row, rhs);
        }
        let Some(mut s) = self.solver.take() else {
            return self.solve_cold();
        };
        let before = s.iterations;

        // Refresh rhs column: new_rhs = B^-1 b_new, where column k of B^-1
        // is the current tableau column that initially held row k's identity.
        let m = s.m;
        let ncols = s.ncols;
        let stride = ncols + 1;
        let b_new: Vec<f64> = (0..m)
            .map(|k| s.row_sign[k] * self.problem.constraints[k].rhs)
            .collect();
        let mut fresh = vec![0.0; m];
        for k in 0..m {
            let bk = b_new[k];
            if bk == 0.0 {
                continue;
            }
            let col = s.idcol[k];
            for (i, f) in fresh.iter_mut().enumerate() {
                *f += s.tab[i * stride + col] * bk;
            }
        }
        for (i, f) in fresh.iter().enumerate() {
            s.tab[i * stride + ncols] = *f;
        }

        match s.dual_iterate() {
            Ok(()) => {
                let sol = s.extract();
                self.last_iterations = s.iterations - before;
                self.last_was_warm = true;
                self.solver = Some(s);
                Ok(sol)
            }
            Err(SimplexError::Infeasible(v)) => {
                self.last_was_warm = true;
                Err(SimplexError::Infeasible(v))
            }
            Err(_) => {
                // numerical trouble: rebuild cold
                self.solve_cold()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{LpProblem, Relation::*};
    use crate::rng::Rng;

    fn lpp1_toy(load0: f64, load1: f64) -> LpProblem {
        // 2 experts × 2 gpus, both EDP groups = {0,1}; vars x00 x01 x10 x11 t
        let mut p = LpProblem::new(5);
        p.set_objective(4, 1.0);
        p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, load0);
        p.add(vec![(2, 1.0), (3, 1.0)], Eq, load1);
        p
    }

    #[test]
    fn warm_matches_cold_across_rhs_changes() {
        let mut warm = WarmSolver::new(lpp1_toy(10.0, 2.0));
        let s0 = warm.solve_cold().unwrap();
        assert!((s0.objective - 6.0).abs() < 1e-7);

        for (l0, l1) in [(4.0, 4.0), (20.0, 0.0), (1.0, 7.0), (100.0, 50.0)] {
            let sw = warm.resolve(&[(2, l0), (3, l1)]).unwrap();
            let sc = crate::lp::simplex::solve(&lpp1_toy(l0, l1)).unwrap();
            assert!(
                (sw.objective - sc.objective).abs() < 1e-6,
                "loads ({l0},{l1}): warm {} cold {}",
                sw.objective,
                sc.objective
            );
            assert!(warm.problem().is_feasible(&sw.x, 1e-6));
        }
    }

    #[test]
    fn warm_uses_fewer_pivots() {
        let mut warm = WarmSolver::new(lpp1_toy(10.0, 2.0));
        warm.solve_cold().unwrap();
        let cold_iters = warm.last_iterations;
        warm.resolve(&[(2, 11.0), (3, 3.0)]).unwrap();
        assert!(warm.last_was_warm);
        assert!(
            warm.last_iterations <= cold_iters,
            "warm {} > cold {}",
            warm.last_iterations,
            cold_iters
        );
    }

    #[test]
    fn warm_random_stress_matches_cold() {
        // bigger minimax LP: 4 gpus, 6 experts, random EDP groups of size 2
        let g = 4usize;
        let e = 6usize;
        let mut rng = Rng::new(7);
        let edp: Vec<[usize; 2]> = (0..e)
            .map(|_| {
                let a = rng.below(g as u64) as usize;
                let mut b = rng.below(g as u64) as usize;
                if b == a {
                    b = (a + 1) % g;
                }
                [a, b]
            })
            .collect();
        // vars: x[e][0..2] then t
        let nv = e * 2 + 1;
        let t = nv - 1;
        let build = |loads: &[f64]| {
            let mut p = LpProblem::new(nv);
            p.set_objective(t, 1.0);
            for gi in 0..g {
                let mut terms = vec![(t, -1.0)];
                for (ei, grp) in edp.iter().enumerate() {
                    for (r, &gg) in grp.iter().enumerate() {
                        if gg == gi {
                            terms.push((ei * 2 + r, 1.0));
                        }
                    }
                }
                p.add(terms, Le, 0.0);
            }
            for (ei, _) in edp.iter().enumerate() {
                p.add(vec![(ei * 2, 1.0), (ei * 2 + 1, 1.0)], Eq, loads[ei]);
            }
            p
        };
        let loads0: Vec<f64> = (0..e).map(|_| rng.below(100) as f64).collect();
        let mut warm = WarmSolver::new(build(&loads0));
        warm.solve_cold().unwrap();
        for round in 0..30 {
            let loads: Vec<f64> = (0..e).map(|_| rng.below(100) as f64).collect();
            let updates: Vec<(usize, f64)> =
                loads.iter().enumerate().map(|(ei, &l)| (g + ei, l)).collect();
            let sw = warm.resolve(&updates).unwrap();
            let sc = crate::lp::simplex::solve(&build(&loads)).unwrap();
            assert!(
                (sw.objective - sc.objective).abs() < 1e-5,
                "round {round}: warm {} cold {}",
                sw.objective,
                sc.objective
            );
        }
    }

    #[test]
    fn resolve_without_prior_solve_falls_back_to_cold() {
        let mut warm = WarmSolver::new(lpp1_toy(10.0, 2.0));
        let s = warm.resolve(&[(2, 8.0)]).unwrap();
        assert!((s.objective - 5.0).abs() < 1e-7);
        assert!(!warm.last_was_warm);
    }
}
