//! Warm-started LP re-solve (the paper's §5.1 optimization).
//!
//! Across micro-batches the LPP-1/LPP-4 constraint *matrix* is fixed by the
//! expert placement; only the rhs (`load_e`) and the variable bounds
//! (`input_e^g` caps, which the revised backend keeps out of the rows
//! entirely) change. The optimal basis of micro-batch *k* therefore stays
//! dual-feasible for micro-batch *k+1*, and a handful of dual-simplex
//! pivots restore primal feasibility — orders of magnitude cheaper than a
//! cold two-phase solve (Fig. 11's "warm solving" ablation).
//!
//! [`WarmSolver`] hides the backend choice behind [`SolverKind`]:
//! [`SolverKind::Revised`] — the production path, itself parameterized by
//! [`Pricing`] (Dantzig vs devex candidate-list) and [`FactorKind`] (dense
//! explicit `B⁻¹` vs sparse LU with Forrest–Tomlin updates) so the
//! `ablation_solvers` bench can measure every (pricing × factorization)
//! cell — or [`SolverKind::DenseTableau`], the full-tableau baseline kept
//! for ablations and differential testing. Any warm-path failure —
//! including a dual-simplex `Infeasible`, which can be a numerical
//! artifact of a stale basis — falls back to a cold re-solve rather than
//! poisoning or dropping the retained state.

use super::bounds;
use super::budget::SolveBudget;
use super::factor::FactorKind;
use super::problem::LpProblem;
use super::revised::{Pricing, RevisedSolver, SolveStats};
use super::simplex::{SimplexError, Solution, Solver};

/// Which simplex implementation backs a [`WarmSolver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Bounded-variable revised simplex (sparse columns, implicit bounds) —
    /// the production path, with its two inner engines selectable.
    Revised {
        /// Column-pricing rule (devex candidate-list vs full Dantzig sweep).
        pricing: Pricing,
        /// Basis-factorization engine (dense inverse vs sparse LU).
        factor: FactorKind,
    },
    /// Dense full-tableau two-phase simplex; bounds are expanded into rows.
    /// Retained as the ablation baseline.
    DenseTableau,
}

impl Default for SolverKind {
    fn default() -> Self {
        SolverKind::Revised { pricing: Pricing::default(), factor: FactorKind::default() }
    }
}

impl SolverKind {
    /// The production configuration: revised simplex, devex pricing,
    /// automatic factorization choice.
    pub fn revised() -> Self {
        Self::default()
    }

    /// Every distinguishable backend cell — the four concrete revised
    /// (pricing × factorization) combinations, then the dense tableau.
    /// The single source of truth for the test suites that must cover
    /// every cell; a new pricing rule or factorization engine added here
    /// propagates to the differential/certificate/golden coverage
    /// automatically.
    pub fn all_cells() -> [SolverKind; 5] {
        [
            SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::DenseInverse },
            SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::SparseLu },
            SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::DenseInverse },
            SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::SparseLu },
            SolverKind::DenseTableau,
        ]
    }

    /// Compact cell label for bench tables (`devex+lu`, `tableau`, …).
    pub fn label(self) -> &'static str {
        match self {
            SolverKind::DenseTableau => "tableau",
            SolverKind::Revised { pricing, factor } => match (pricing, factor) {
                (Pricing::Dantzig, FactorKind::DenseInverse) => "dantzig+dense",
                (Pricing::Dantzig, FactorKind::SparseLu) => "dantzig+lu",
                (Pricing::Dantzig, FactorKind::Auto) => "dantzig+auto",
                (Pricing::Devex, FactorKind::DenseInverse) => "devex+dense",
                (Pricing::Devex, FactorKind::SparseLu) => "devex+lu",
                (Pricing::Devex, FactorKind::Auto) => "devex+auto",
            },
        }
    }
}

enum Backend {
    Revised {
        slot: Option<RevisedSolver>,
        pricing: Pricing,
        factor: FactorKind,
    },
    Dense {
        solver: Option<Solver>,
        /// bound-expanded clone of the problem + per-variable bound-row map
        expanded: LpProblem,
        bound_row: Vec<Option<usize>>,
    },
}

/// A solver that remembers its optimal basis between solves.
///
/// # Example
///
/// Solve cold once, then warm re-solve after a variable-bound edit (the
/// LPP-4 per-micro-batch pattern — only `input_e^g` caps move):
///
/// ```
/// use micromoe::lp::{LpProblem, Relation, WarmSolver};
///
/// // min -l0 - l1  s.t.  l0 + l1 ≤ 8,  l0 ≤ 3,  l1 ≤ 3
/// let mut p = LpProblem::new(2);
/// p.set_objective(0, -1.0);
/// p.set_objective(1, -1.0);
/// p.set_upper(0, 3.0);
/// p.set_upper(1, 3.0);
/// p.add(vec![(0, 1.0), (1, 1.0)], Relation::Le, 8.0);
///
/// let mut warm = WarmSolver::new(p);
/// let s0 = warm.solve_cold().unwrap();
/// assert!((s0.objective - (-6.0)).abs() < 1e-9);
///
/// // next micro-batch: both caps rise to 5 — warm repair, no cold solve
/// let s1 = warm.resolve_with_bounds(&[], &[(0, 5.0), (1, 5.0)]).unwrap();
/// assert!((s1.objective - (-8.0)).abs() < 1e-9);
/// assert!(warm.last_was_warm);
/// ```
pub struct WarmSolver {
    backend: Backend,
    problem: LpProblem,
    /// Pivots spent on the most recent solve (cold or warm).
    pub last_iterations: usize,
    /// Whether the most recent solve used the warm path.
    pub last_was_warm: bool,
    /// Full work counters for the most recent solve — pivots, dual pivots,
    /// bound flips, refactorizations ([`SolveStats`]). The dense tableau
    /// backend reports pivots only (it has neither implicit bounds nor a
    /// maintained factorization).
    pub last_stats: SolveStats,
    /// Why the most recent *warm attempt* failed before the automatic cold
    /// fallback ran (`None` when the warm path succeeded, was skipped, or
    /// was never tried). Lets the degradation ladder attribute a cold solve
    /// to a warm budget exhaustion vs a numerical stall.
    pub last_warm_failure: Option<SimplexError>,
    /// Per-solve budget applied to every revised-backend attempt (cold and
    /// warm). The dense tableau baseline does not enforce budgets.
    budget: SolveBudget,
}

impl WarmSolver {
    /// Production-configuration warm solver (see [`SolverKind::revised`]).
    pub fn new(problem: LpProblem) -> Self {
        Self::with_kind(problem, SolverKind::default())
    }

    /// Warm solver with an explicit backend choice.
    pub fn with_kind(problem: LpProblem, kind: SolverKind) -> Self {
        let backend = match kind {
            SolverKind::Revised { pricing, factor } => {
                Backend::Revised { slot: None, pricing, factor }
            }
            SolverKind::DenseTableau => {
                let (expanded, bound_row) = bounds::expand_to_rows(&problem);
                Backend::Dense { solver: None, expanded, bound_row }
            }
        };
        WarmSolver {
            backend,
            problem,
            last_iterations: 0,
            last_was_warm: false,
            last_stats: SolveStats::default(),
            last_warm_failure: None,
            budget: SolveBudget::default(),
        }
    }

    /// Set the per-solve budget for all subsequent attempts. Applies to the
    /// retained revised solver immediately and to every future cold solve.
    /// The dense tableau backend ignores budgets (ablation baseline only).
    pub fn set_budget(&mut self, budget: SolveBudget) {
        self.budget = budget;
        if let Backend::Revised { slot: Some(s), .. } = &mut self.backend {
            s.set_budget(budget);
        }
    }

    /// The per-solve budget currently in force.
    pub fn budget(&self) -> SolveBudget {
        self.budget
    }

    /// The backend this solver was built with.
    pub fn kind(&self) -> SolverKind {
        match &self.backend {
            Backend::Revised { pricing, factor, .. } => {
                SolverKind::Revised { pricing: *pricing, factor: *factor }
            }
            Backend::Dense { .. } => SolverKind::DenseTableau,
        }
    }

    /// The (bound-form) problem being solved, with all updates applied.
    pub fn problem(&self) -> &LpProblem {
        &self.problem
    }

    /// Solve from scratch (two-phase primal), replacing any retained basis.
    pub fn solve_cold(&mut self) -> Result<Solution, SimplexError> {
        self.last_was_warm = false;
        self.last_warm_failure = None;
        match &mut self.backend {
            Backend::Revised { slot, pricing, factor } => {
                *slot = None;
                let mut s = RevisedSolver::with_config(&self.problem, *pricing, *factor);
                s.set_budget(self.budget);
                let sol = s.solve()?;
                self.last_iterations = s.iterations;
                self.last_stats = s.stats();
                *slot = Some(s);
                Ok(sol)
            }
            Backend::Dense { solver, expanded, .. } => {
                *solver = None;
                let mut s = Solver::new(expanded);
                let sol = s.solve()?;
                self.last_iterations = s.iterations;
                self.last_stats = SolveStats { pivots: s.iterations, ..SolveStats::default() };
                *solver = Some(s);
                Ok(sol)
            }
        }
    }

    /// Apply rhs updates then solve, warm when allowed and possible.
    pub fn solve_with(
        &mut self,
        updates: &[(usize, f64)],
        use_warm: bool,
    ) -> Result<Solution, SimplexError> {
        self.solve_with_bounds(updates, &[], use_warm)
    }

    /// Apply rhs *and* variable-bound updates then solve. Bound updates are
    /// (variable index, new upper bound) pairs — the revised backend edits
    /// the bound directly; the dense backend rewrites the rhs of the
    /// synthetic bound row.
    pub fn solve_with_bounds(
        &mut self,
        rhs_updates: &[(usize, f64)],
        bound_updates: &[(usize, f64)],
        use_warm: bool,
    ) -> Result<Solution, SimplexError> {
        if use_warm {
            self.resolve_with_bounds(rhs_updates, bound_updates)
        } else {
            self.apply_updates(rhs_updates, bound_updates);
            self.solve_cold()
        }
    }

    /// Re-solve after changing some rhs values (original row order).
    pub fn resolve(&mut self, updates: &[(usize, f64)]) -> Result<Solution, SimplexError> {
        self.resolve_with_bounds(updates, &[])
    }

    fn apply_updates(&mut self, rhs_updates: &[(usize, f64)], bound_updates: &[(usize, f64)]) {
        for &(row, rhs) in rhs_updates {
            self.problem.set_rhs(row, rhs);
        }
        for &(var, ub) in bound_updates {
            self.problem.set_upper(var, ub);
        }
        if let Backend::Dense { solver, expanded, bound_row } = &mut self.backend {
            // The row expansion is shaped by which bounds were finite at
            // build time. A bound appearing on a variable that had none (or
            // one going infinite, which no `≤` row can express) changes
            // that shape: rebuild the expansion from the updated problem
            // and drop the retained basis so the next solve starts cold.
            let reshaped = bound_updates.iter().any(|&(var, ub)| {
                bound_row[var].is_none() || !ub.is_finite()
            });
            if reshaped {
                let (e2, b2) = bounds::expand_to_rows(&self.problem);
                *expanded = e2;
                *bound_row = b2;
                *solver = None;
                return;
            }
            for &(row, rhs) in rhs_updates {
                expanded.set_rhs(row, rhs);
            }
            for &(var, ub) in bound_updates {
                let row = bound_row[var].expect("reshape handled above");
                expanded.set_rhs(row, ub);
            }
        }
    }

    /// Re-solve after rhs/bound updates, reusing the retained basis when
    /// one exists. Falls back to a cold solve when no basis is retained or
    /// the dual simplex fails for any reason (including `Infeasible`, which
    /// a stale basis can report spuriously — the cold solve is the
    /// authority on true infeasibility).
    pub fn resolve_with_bounds(
        &mut self,
        rhs_updates: &[(usize, f64)],
        bound_updates: &[(usize, f64)],
    ) -> Result<Solution, SimplexError> {
        self.apply_updates(rhs_updates, bound_updates);
        match self.try_warm(rhs_updates, bound_updates) {
            Some(Ok(sol)) => {
                self.last_warm_failure = None;
                Ok(sol)
            }
            // the warm dual stalled, erred, or ran out of budget: cold,
            // remembering why the warm rung was skipped
            Some(Err(warm_err)) => {
                let cold = self.solve_cold();
                self.last_warm_failure = Some(warm_err);
                cold
            }
            // no retained basis yet: plain cold solve
            None => self.solve_cold(),
        }
    }

    /// Attempt the warm dual re-solve; `None` when no basis is retained.
    fn try_warm(
        &mut self,
        rhs_updates: &[(usize, f64)],
        bound_updates: &[(usize, f64)],
    ) -> Option<Result<Solution, SimplexError>> {
        let (result, stats) = match &mut self.backend {
            Backend::Revised { slot, .. } => {
                let s = slot.as_mut()?;
                let before = s.stats();
                for &(row, rhs) in rhs_updates {
                    s.update_rhs(row, rhs);
                }
                for &(var, ub) in bound_updates {
                    s.update_upper(var, ub);
                }
                let r = s.warm_resolve();
                let spent = s.stats().since(before);
                (r, spent)
            }
            Backend::Dense { solver, expanded, .. } => {
                let s = solver.as_mut()?;
                let before = s.iterations;
                // Refresh rhs column: new_rhs = B⁻¹ b_new, where column k of
                // B⁻¹ is the tableau column that initially held row k's
                // identity.
                let m = s.m;
                let ncols = s.ncols;
                let stride = ncols + 1;
                let b_new: Vec<f64> = (0..m)
                    .map(|k| s.row_sign[k] * expanded.constraints[k].rhs)
                    .collect();
                let mut fresh = vec![0.0; m];
                for (k, &bk) in b_new.iter().enumerate() {
                    if bk == 0.0 {
                        continue;
                    }
                    let col = s.idcol[k];
                    for (i, f) in fresh.iter_mut().enumerate() {
                        *f += s.tab[i * stride + col] * bk;
                    }
                }
                for (i, f) in fresh.iter().enumerate() {
                    s.tab[i * stride + ncols] = *f;
                }
                let r = s.dual_iterate().map(|()| s.extract());
                let spent = s.iterations - before;
                (r, SolveStats { pivots: spent, ..SolveStats::default() })
            }
        };
        if result.is_ok() {
            self.last_iterations = stats.pivots;
            self.last_was_warm = true;
            self.last_stats = stats;
        }
        Some(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{LpProblem, Relation::*};
    use crate::rng::Rng;

    fn lpp1_toy(load0: f64, load1: f64) -> LpProblem {
        // 2 experts × 2 gpus, both EDP groups = {0,1}; vars x00 x01 x10 x11 t
        let mut p = LpProblem::new(5);
        p.set_objective(4, 1.0);
        p.add(vec![(0, 1.0), (2, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(1, 1.0), (3, 1.0), (4, -1.0)], Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Eq, load0);
        p.add(vec![(2, 1.0), (3, 1.0)], Eq, load1);
        p
    }

    /// Every backend cell: four revised (pricing × factorization) combos
    /// plus the dense tableau.
    fn all_kinds() -> [SolverKind; 5] {
        SolverKind::all_cells()
    }

    #[test]
    fn labels_are_unique() {
        let mut labels: Vec<&str> = all_kinds().iter().map(|k| k.label()).collect();
        labels.push(SolverKind::default().label());
        let n = labels.len();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), n, "duplicate SolverKind labels");
    }

    #[test]
    fn default_kind_is_devex_auto() {
        assert_eq!(
            SolverKind::default(),
            SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::Auto }
        );
        assert_eq!(SolverKind::revised(), SolverKind::default());
    }

    #[test]
    fn warm_matches_cold_across_rhs_changes() {
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(lpp1_toy(10.0, 2.0), kind);
            let s0 = warm.solve_cold().unwrap();
            assert!((s0.objective - 6.0).abs() < 1e-7, "{kind:?}");

            for (l0, l1) in [(4.0, 4.0), (20.0, 0.0), (1.0, 7.0), (100.0, 50.0)] {
                let sw = warm.resolve(&[(2, l0), (3, l1)]).unwrap();
                let sc = crate::lp::simplex::solve(&lpp1_toy(l0, l1)).unwrap();
                assert!(
                    (sw.objective - sc.objective).abs() < 1e-6,
                    "{kind:?} loads ({l0},{l1}): warm {} cold {}",
                    sw.objective,
                    sc.objective
                );
                assert!(warm.problem().is_feasible(&sw.x, 1e-6));
            }
        }
    }

    #[test]
    fn warm_uses_fewer_pivots() {
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(lpp1_toy(10.0, 2.0), kind);
            warm.solve_cold().unwrap();
            let cold_iters = warm.last_iterations;
            warm.resolve(&[(2, 11.0), (3, 3.0)]).unwrap();
            assert!(warm.last_was_warm, "{kind:?}");
            assert!(
                warm.last_iterations <= cold_iters,
                "{kind:?}: warm {} > cold {}",
                warm.last_iterations,
                cold_iters
            );
        }
    }

    #[test]
    fn warm_bound_updates_match_cold() {
        // LPP-4 shape in miniature: l-vars capped by per-batch inputs,
        // expressed as variable bounds and updated warm.
        let build = |cap0: f64, cap1: f64| {
            // min -l0 - l1 s.t. l0 + l1 <= 8, l0 <= cap0, l1 <= cap1
            let mut p = LpProblem::new(2);
            p.set_objective(0, -1.0);
            p.set_objective(1, -1.0);
            p.set_upper(0, cap0);
            p.set_upper(1, cap1);
            p.add(vec![(0, 1.0), (1, 1.0)], Le, 8.0);
            p
        };
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(build(3.0, 3.0), kind);
            let s0 = warm.solve_cold().unwrap();
            assert!((s0.objective + 6.0).abs() < 1e-7, "{kind:?}");
            for (c0, c1) in [(5.0, 5.0), (0.0, 2.0), (8.0, 8.0), (1.0, 0.0)] {
                let sw = warm.resolve_with_bounds(&[], &[(0, c0), (1, c1)]).unwrap();
                let sc_obj = -(c0 + c1).min(8.0);
                assert!(
                    (sw.objective - sc_obj).abs() < 1e-6,
                    "{kind:?} caps ({c0},{c1}): warm {} expect {sc_obj}",
                    sw.objective
                );
                assert!(warm.problem().is_feasible(&sw.x, 1e-6), "{kind:?}");
            }
        }
    }

    #[test]
    fn infeasible_resolve_recovers_to_cold_afterwards() {
        // An infeasible warm resolve must not poison the retained state —
        // the next feasible resolve should still succeed (and warm solves
        // must resume once state is rebuilt).
        for kind in all_kinds() {
            // x0 >= lo (Ge row), x0 <= 5 (bound). lo > 5 is infeasible.
            let mut p = LpProblem::new(1);
            p.set_objective(0, 1.0);
            p.set_upper(0, 5.0);
            p.add(vec![(0, 1.0)], Ge, 1.0);
            let mut warm = WarmSolver::with_kind(p, kind);
            warm.solve_cold().unwrap();
            let err = warm.resolve(&[(0, 7.0)]).unwrap_err();
            assert!(matches!(err, SimplexError::Infeasible(_)), "{kind:?}: {err}");
            // back to feasible: must solve, then warm again on the next call
            let s = warm.resolve(&[(0, 4.0)]).unwrap();
            assert!((s.objective - 4.0).abs() < 1e-7, "{kind:?}");
            let s2 = warm.resolve(&[(0, 2.0)]).unwrap();
            assert!((s2.objective - 2.0).abs() < 1e-7, "{kind:?}");
            assert!(warm.last_was_warm, "{kind:?}: warm path not restored");
        }
    }

    #[test]
    fn warm_random_stress_matches_cold() {
        // bigger minimax LP: 4 gpus, 6 experts, random EDP groups of size 2
        let g = 4usize;
        let e = 6usize;
        let mut rng = Rng::new(7);
        let edp: Vec<[usize; 2]> = (0..e)
            .map(|_| {
                let a = rng.below(g as u64) as usize;
                let mut b = rng.below(g as u64) as usize;
                if b == a {
                    b = (a + 1) % g;
                }
                [a, b]
            })
            .collect();
        // vars: x[e][0..2] then t
        let nv = e * 2 + 1;
        let t = nv - 1;
        let build = |loads: &[f64]| {
            let mut p = LpProblem::new(nv);
            p.set_objective(t, 1.0);
            for gi in 0..g {
                let mut terms = vec![(t, -1.0)];
                for (ei, grp) in edp.iter().enumerate() {
                    for (r, &gg) in grp.iter().enumerate() {
                        if gg == gi {
                            terms.push((ei * 2 + r, 1.0));
                        }
                    }
                }
                p.add(terms, Le, 0.0);
            }
            for (ei, _) in edp.iter().enumerate() {
                p.add(vec![(ei * 2, 1.0), (ei * 2 + 1, 1.0)], Eq, loads[ei]);
            }
            p
        };
        let loads0: Vec<f64> = (0..e).map(|_| rng.below(100) as f64).collect();
        for (ki, kind) in all_kinds().into_iter().enumerate() {
            let mut warm = WarmSolver::with_kind(build(&loads0), kind);
            warm.solve_cold().unwrap();
            let mut rng2 = rng.fork(ki as u64);
            for round in 0..30 {
                let loads: Vec<f64> = (0..e).map(|_| rng2.below(100) as f64).collect();
                let updates: Vec<(usize, f64)> =
                    loads.iter().enumerate().map(|(ei, &l)| (g + ei, l)).collect();
                let sw = warm.resolve(&updates).unwrap();
                let sc = crate::lp::simplex::solve(&build(&loads)).unwrap();
                assert!(
                    (sw.objective - sc.objective).abs() < 1e-5,
                    "{kind:?} round {round}: warm {} cold {}",
                    sw.objective,
                    sc.objective
                );
            }
        }
    }

    #[test]
    fn resolve_without_prior_solve_falls_back_to_cold() {
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(lpp1_toy(10.0, 2.0), kind);
            let s = warm.resolve(&[(2, 8.0)]).unwrap();
            assert!((s.objective - 5.0).abs() < 1e-7, "{kind:?}");
            assert!(!warm.last_was_warm, "{kind:?}");
        }
    }

    #[test]
    fn budget_threads_through_warm_solver() {
        use crate::lp::budget::SolveBudget;
        // revised cells only — the dense tableau baseline ignores budgets
        for kind in all_kinds() {
            if kind == SolverKind::DenseTableau {
                continue;
            }
            let mut warm = WarmSolver::with_kind(lpp1_toy(10.0, 2.0), kind);
            warm.set_budget(SolveBudget::with_max_pivots(0));
            let err = warm.solve_cold().unwrap_err();
            assert!(matches!(err, SimplexError::BudgetExhausted(_)), "{kind:?}: {err}");
            // lift the cap: the same solver state recovers
            warm.set_budget(SolveBudget::unlimited());
            warm.solve_cold().unwrap();
            // starved again: the warm attempt exhausts, the automatic cold
            // fallback exhausts too, and the warm failure is attributed
            warm.set_budget(SolveBudget::with_max_pivots(0));
            let err = warm.resolve(&[(2, 40.0)]).unwrap_err();
            assert!(matches!(err, SimplexError::BudgetExhausted(_)), "{kind:?}: {err}");
            assert!(
                matches!(warm.last_warm_failure, Some(SimplexError::BudgetExhausted(_))),
                "{kind:?}: warm failure not recorded"
            );
        }
    }
}
