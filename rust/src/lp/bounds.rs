//! Variable-bound utilities shared by the two solver backends.
//!
//! The revised simplex treats `0 ≤ x_j ≤ u_j` implicitly (no rows); the
//! dense tableau cannot, so [`expand_to_rows`] lowers finite bounds into
//! ordinary `x_j ≤ u_j` constraint rows appended after the real rows. The
//! returned map lets a warm solver translate per-micro-batch *bound*
//! updates into *rhs* updates on those synthetic rows, keeping the two
//! backends behaviourally identical (the property the differential fuzz
//! suite pins down).

use super::problem::{LpProblem, Relation};

/// Rewrite every finite upper bound of `p` as an explicit `≤` row.
///
/// Returns the expanded (bound-free) problem plus, per variable, the index
/// of the row now carrying its bound (`None` for unbounded variables). The
/// synthetic rows sit after all original rows, so original row indices are
/// preserved — rhs-update paths keep working untranslated.
pub fn expand_to_rows(p: &LpProblem) -> (LpProblem, Vec<Option<usize>>) {
    let mut out = p.clone();
    let mut bound_row = vec![None; p.num_vars];
    for v in 0..p.num_vars {
        let u = p.upper[v];
        if u.is_finite() {
            let row = out.add(vec![(v, 1.0)], Relation::Le, u);
            bound_row[v] = Some(row);
        }
    }
    for u in &mut out.upper {
        *u = f64::INFINITY;
    }
    (out, bound_row)
}

/// Sparse matrix in compressed-sparse-column form — the standard-form
/// constraint matrix of the revised simplex (structural + slack +
/// artificial columns). Column access is what pricing, FTRAN, and
/// refactorization need; rows are never traversed.
#[derive(Clone, Debug)]
pub struct Csc {
    /// Row count.
    pub m: usize,
    /// Column count.
    pub ncols: usize,
    /// Per-column start offsets into `row_idx`/`val` (len `ncols + 1`).
    pub col_ptr: Vec<usize>,
    /// Row index of each nonzero, column-major.
    pub row_idx: Vec<usize>,
    /// Value of each nonzero, column-major.
    pub val: Vec<f64>,
}

impl Csc {
    /// Build from per-column (row, value) lists.
    pub fn from_columns(m: usize, cols: Vec<Vec<(usize, f64)>>) -> Csc {
        let ncols = cols.len();
        let nnz: usize = cols.iter().map(Vec::len).sum();
        let mut col_ptr = Vec::with_capacity(ncols + 1);
        let mut row_idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for col in &cols {
            for &(i, a) in col {
                debug_assert!(i < m);
                row_idx.push(i);
                val.push(a);
            }
            col_ptr.push(row_idx.len());
        }
        Csc { m, ncols, col_ptr, row_idx, val }
    }

    /// The (rows, values) slices of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[a..b], &self.val[a..b])
    }

    /// Sparse dot of column `j` with a dense vector.
    #[inline]
    pub fn col_dot(&self, j: usize, dense: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        rows.iter().zip(vals).map(|(&i, &a)| dense[i] * a).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_preserves_rows_and_maps_bounds() {
        let mut p = LpProblem::new(3);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Le, 5.0);
        p.set_upper(0, 2.0);
        p.set_upper(2, 0.0);
        let (exp, map) = expand_to_rows(&p);
        assert_eq!(exp.constraints.len(), 3); // 1 real + 2 bound rows
        assert!(!exp.has_finite_upper());
        assert_eq!(map, vec![Some(1), None, Some(2)]);
        assert_eq!(exp.constraints[1].terms, vec![(0, 1.0)]);
        assert_eq!(exp.constraints[1].rhs, 2.0);
        assert_eq!(exp.constraints[2].rhs, 0.0);
        // original rows keep their indices
        assert_eq!(exp.constraints[0].rhs, 5.0);
    }

    #[test]
    fn expanded_feasibility_matches_bounded() {
        let mut p = LpProblem::new(2);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Le, 10.0);
        p.set_upper(1, 4.0);
        let (exp, _) = expand_to_rows(&p);
        for cand in [[1.0, 1.0], [1.0, 5.0], [11.0, 0.0]] {
            assert_eq!(p.is_feasible(&cand, 1e-9), exp.is_feasible(&cand, 1e-9));
        }
    }

    #[test]
    fn csc_column_access() {
        // A = [[1, 0], [2, 3]]
        let csc = Csc::from_columns(2, vec![vec![(0, 1.0), (1, 2.0)], vec![(1, 3.0)]]);
        assert_eq!(csc.col(0), (&[0usize, 1][..], &[1.0, 2.0][..]));
        assert_eq!(csc.col(1), (&[1usize][..], &[3.0][..]));
        assert_eq!(csc.col_dot(0, &[10.0, 1.0]), 12.0);
        assert_eq!(csc.col_dot(1, &[10.0, 1.0]), 3.0);
    }
}
