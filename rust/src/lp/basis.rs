//! Explicit basis-inverse maintenance for the revised simplex.
//!
//! Keeps `B⁻¹` as a dense row-major m×m matrix. Each pivot applies a
//! product-form (eta) update in O(m²); every [`REFACTOR_EVERY`] updates the
//! inverse is rebuilt from the basis columns by Gauss–Jordan elimination
//! with partial pivoting (O(m³), amortized to O(m²) per pivot), which also
//! flushes accumulated floating-point drift. At the paper's largest scale
//! (64 GPUs / 256 experts) m is a few hundred, so the dense inverse is
//! cheap to hold and the eta update — not the O(m·ncols) full-tableau
//! sweep — dominates per-pivot cost. Past that scale the O(m²) memory and
//! sparsity-blind eta update lose to [`super::lu::SparseLu`]'s fill-aware
//! factors; [`super::factor::FactorKind::Auto`] makes the cut at build
//! time, keeping this engine as the small-`m` fast path and the ablation
//! baseline.

use super::bounds::Csc;
use super::factor::Factorization;

/// Floor on the eta-update count between refactorizations. The effective
/// interval is `max(REFACTOR_EVERY, m)`: the rebuild is O(m³), so tying it
/// to `m` keeps the amortized refactor cost at O(m²) per pivot — the same
/// order as the eta update itself — instead of letting the rebuild
/// dominate at large `m`.
pub const REFACTOR_EVERY: usize = 64;

/// Pivots smaller than this are numerically unusable.
const PIVOT_TOL: f64 = 1e-10;

/// Numerical failure inside a basis-factorization engine. Every variant
/// means the caller should refactorize (and, failing that, treat the basis
/// as unusable and fall back to a cold solve).
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum BasisError {
    /// The basis columns are (numerically) linearly dependent.
    #[error("singular basis (pivot {0:.3e} at elimination step {1})")]
    Singular(f64, usize),
    /// A pivot-update element was too small to divide by safely.
    #[error("eta pivot too small ({0:.3e})")]
    TinyPivot(f64),
}

/// Dense m×m basis inverse with product-form updates.
#[derive(Clone, Debug)]
pub struct BasisInverse {
    m: usize,
    /// row-major m×m, `inv[i*m + j]`
    inv: Vec<f64>,
    updates: usize,
}

impl BasisInverse {
    /// Identity inverse (the initial slack/artificial basis is an identity).
    pub fn identity(m: usize) -> Self {
        let mut inv = vec![0.0; m * m];
        for i in 0..m {
            inv[i * m + i] = 1.0;
        }
        BasisInverse { m, inv, updates: 0 }
    }

    /// Row count of the (square) basis.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Whether enough eta updates accumulated to warrant a refactorization.
    pub fn due_for_refactor(&self) -> bool {
        self.updates >= REFACTOR_EVERY.max(self.m)
    }

    /// Row `r` of `B⁻¹` (this is `e_r' B⁻¹`, the BTRAN of a unit vector).
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.inv[r * self.m..(r + 1) * self.m]
    }

    /// FTRAN against a sparse column: `out = B⁻¹ a`, O(m · nnz(a)).
    pub fn ftran_sparse(&self, rows: &[usize], vals: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m);
        out.fill(0.0);
        for (&i, &a) in rows.iter().zip(vals) {
            if a == 0.0 {
                continue;
            }
            for (k, o) in out.iter_mut().enumerate() {
                *o += self.inv[k * self.m + i] * a;
            }
        }
    }

    /// Dense mat-vec: `out = B⁻¹ v` (used when refreshing `x_B`), O(m²)
    /// skipping zero entries of `v`.
    pub fn ftran_dense(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.m);
        debug_assert_eq!(out.len(), self.m);
        out.fill(0.0);
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            for (k, o) in out.iter_mut().enumerate() {
                *o += self.inv[k * self.m + i] * vi;
            }
        }
    }

    /// BTRAN of the basic cost vector: `y = c_B' B⁻¹`, with `cb` given as
    /// (basis row, cost) pairs for the nonzero basic costs only.
    pub fn btran_costs(&self, cb: &[(usize, f64)], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.m);
        out.fill(0.0);
        for &(k, c) in cb {
            if c == 0.0 {
                continue;
            }
            let row = &self.inv[k * self.m..(k + 1) * self.m];
            for (o, &r) in out.iter_mut().zip(row) {
                *o += c * r;
            }
        }
    }

    /// Product-form update after a pivot: the entering column's FTRAN image
    /// is `w`, the leaving basic variable sits in row `r`. Replaces `B⁻¹`
    /// with `E B⁻¹` where `E` is the eta matrix of the pivot. O(m²).
    pub fn update(&mut self, w: &[f64], r: usize) -> Result<(), BasisError> {
        let m = self.m;
        let wr = w[r];
        if wr.abs() < PIVOT_TOL {
            return Err(BasisError::TinyPivot(wr));
        }
        let inv_wr = 1.0 / wr;
        // scale pivot row
        for v in &mut self.inv[r * m..(r + 1) * m] {
            *v *= inv_wr;
        }
        // eliminate w from every other row
        for i in 0..m {
            if i == r {
                continue;
            }
            let f = w[i];
            if f == 0.0 {
                continue;
            }
            let (head, tail) = self.inv.split_at_mut(r.max(i) * m);
            let (row_i, row_r) = if i < r {
                (&mut head[i * m..(i + 1) * m], &tail[..m])
            } else {
                (&mut tail[..m], &head[r * m..(r + 1) * m])
            };
            for (a, &b) in row_i.iter_mut().zip(row_r) {
                *a -= f * b;
            }
        }
        self.updates += 1;
        Ok(())
    }

    /// Rebuild `B⁻¹` from the basis columns of `csc` by Gauss–Jordan with
    /// partial pivoting. Resets the eta-update counter.
    pub fn refactor(&mut self, csc: &Csc, basis: &[usize]) -> Result<(), BasisError> {
        let m = self.m;
        debug_assert_eq!(basis.len(), m);
        // dense B, row-major
        let mut b = vec![0.0; m * m];
        for (col, &j) in basis.iter().enumerate() {
            let (rows, vals) = csc.col(j);
            for (&i, &a) in rows.iter().zip(vals) {
                b[i * m + col] = a;
            }
        }
        let mut inv = vec![0.0; m * m];
        for i in 0..m {
            inv[i * m + i] = 1.0;
        }
        for k in 0..m {
            // partial pivot
            let mut p = k;
            let mut best = b[k * m + k].abs();
            for i in (k + 1)..m {
                let v = b[i * m + k].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < PIVOT_TOL {
                return Err(BasisError::Singular(best, k));
            }
            if p != k {
                for j in 0..m {
                    b.swap(k * m + j, p * m + j);
                    inv.swap(k * m + j, p * m + j);
                }
            }
            let piv = b[k * m + k];
            let inv_piv = 1.0 / piv;
            for j in 0..m {
                b[k * m + j] *= inv_piv;
                inv[k * m + j] *= inv_piv;
            }
            for i in 0..m {
                if i == k {
                    continue;
                }
                let f = b[i * m + k];
                if f == 0.0 {
                    continue;
                }
                for j in 0..m {
                    b[i * m + j] -= f * b[k * m + j];
                    inv[i * m + j] -= f * inv[k * m + j];
                }
            }
        }
        self.inv = inv;
        self.updates = 0;
        Ok(())
    }
}

impl Factorization for BasisInverse {
    fn m(&self) -> usize {
        BasisInverse::m(self)
    }

    fn due_for_refactor(&self) -> bool {
        BasisInverse::due_for_refactor(self)
    }

    fn ftran_sparse(&mut self, rows: &[usize], vals: &[f64], out: &mut [f64]) {
        BasisInverse::ftran_sparse(self, rows, vals, out);
    }

    fn ftran_dense(&mut self, v: &[f64], out: &mut [f64]) {
        BasisInverse::ftran_dense(self, v, out);
    }

    fn btran_costs(&mut self, cb: &[(usize, f64)], out: &mut [f64]) {
        BasisInverse::btran_costs(self, cb, out);
    }

    fn btran_unit(&mut self, r: usize, out: &mut [f64]) {
        out.copy_from_slice(self.row(r));
    }

    fn pivot_update(
        &mut self,
        _col_rows: &[usize],
        _col_vals: &[f64],
        w: &[f64],
        r: usize,
    ) -> Result<(), BasisError> {
        self.update(w, r)
    }

    fn refactor(&mut self, csc: &Csc, basis: &[usize]) -> Result<(), BasisError> {
        BasisInverse::refactor(self, csc, basis)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn csc_2x2() -> Csc {
        // A = [[2, 1], [0, 3]] as columns
        Csc::from_columns(2, vec![vec![(0, 2.0)], vec![(0, 1.0), (1, 3.0)]])
    }

    #[test]
    fn refactor_inverts() {
        let csc = csc_2x2();
        let mut b = BasisInverse::identity(2);
        b.refactor(&csc, &[0, 1]).unwrap();
        // B = [[2,1],[0,3]], B^-1 = [[0.5, -1/6], [0, 1/3]]
        let mut out = [0.0; 2];
        b.ftran_dense(&[2.0, 3.0], &mut out); // B^-1 [2,3]' = [0.5, 1]'
        assert!((out[0] - 0.5).abs() < 1e-12);
        assert!((out[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eta_update_matches_refactor() {
        // start with identity basis of a 2-col identity-ish system, then
        // swap in column [1,3]' at row 1 and compare against direct inverse
        let cols = vec![
            vec![(0, 1.0)],           // e0
            vec![(1, 1.0)],           // e1
            vec![(0, 1.0), (1, 3.0)], // a
        ];
        let csc = Csc::from_columns(2, cols);
        let mut b = BasisInverse::identity(2);
        // entering col 2, leaving row 1: w = B^-1 a = a
        let mut w = [0.0; 2];
        let (rows, vals) = csc.col(2);
        b.ftran_sparse(rows, vals, &mut w);
        b.update(&w, 1).unwrap();
        let mut direct = BasisInverse::identity(2);
        direct.refactor(&csc, &[0, 2]).unwrap();
        for r in 0..2 {
            for c in 0..2 {
                assert!(
                    (b.row(r)[c] - direct.row(r)[c]).abs() < 1e-12,
                    "mismatch at ({r},{c})"
                );
            }
        }
    }

    #[test]
    fn singular_basis_detected() {
        let cols = vec![vec![(0, 1.0)], vec![(0, 2.0)]]; // two parallel cols
        let csc = Csc::from_columns(2, cols);
        let mut b = BasisInverse::identity(2);
        assert!(matches!(b.refactor(&csc, &[0, 1]), Err(BasisError::Singular(..))));
    }

    #[test]
    fn tiny_eta_pivot_rejected() {
        let mut b = BasisInverse::identity(2);
        assert!(matches!(b.update(&[1.0, 1e-14], 1), Err(BasisError::TinyPivot(_))));
    }

    #[test]
    fn btran_costs_weights_rows() {
        let b = BasisInverse::identity(3);
        let mut y = [0.0; 3];
        b.btran_costs(&[(0, 2.0), (2, -1.0)], &mut y);
        assert_eq!(y, [2.0, 0.0, -1.0]);
    }

    /// Pins the documented contract of [`REFACTOR_EVERY`]: the *effective*
    /// refactorization interval is `max(REFACTOR_EVERY, m)`, so the O(m³)
    /// rebuild stays amortized O(m²) per pivot at any scale.
    #[test]
    fn effective_refactor_interval_is_max_of_const_and_m() {
        // small m: the constant floor governs
        let m = 2;
        assert!(REFACTOR_EVERY > m);
        let mut b = BasisInverse::identity(m);
        let w = [1.0, 0.0]; // pivot row 0, identity eta
        for _ in 0..REFACTOR_EVERY - 1 {
            b.update(&w, 0).unwrap();
            assert!(!b.due_for_refactor());
        }
        b.update(&w, 0).unwrap();
        assert!(b.due_for_refactor());

        // large m: the row count governs
        let m = REFACTOR_EVERY + 36;
        let mut b = BasisInverse::identity(m);
        let mut w = vec![0.0; m];
        w[0] = 1.0;
        for _ in 0..m - 1 {
            b.update(&w, 0).unwrap();
            assert!(!b.due_for_refactor());
        }
        b.update(&w, 0).unwrap();
        assert!(b.due_for_refactor());
    }
}
