//! Asymmetric, load-aware placement (§6.3).
//!
//! Two stages, exactly as the paper describes:
//!
//! 1. **Replica counts** — greedy: keep a heap of experts keyed by
//!    load-per-replica; give the next replica slot to the current maximum
//!    until all `G · slots_per_gpu` slots are used (every expert gets at
//!    least one).
//! 2. **Replica locations** — Monte-Carlo: sample many random placements
//!    honoring the counts and per-GPU slot budgets; keep the one whose
//!    maximum induced subgraph density (Eq. 3) is minimal.

use super::graph::max_induced_density;
use super::Placement;
use crate::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Greedy replica-count allocation: returns `counts[e] >= 1` summing to
/// `total_slots`, with `counts[e] <= max_count` (an expert cannot have two
/// replicas on one GPU, so `max_count` is the GPU count).
pub fn greedy_replica_counts(loads: &[f64], total_slots: usize, max_count: usize) -> Vec<usize> {
    let e = loads.len();
    assert!(total_slots >= e, "need at least one slot per expert");
    assert!(total_slots <= e * max_count, "more slots than placeable replicas");

    #[derive(PartialEq)]
    struct Item {
        per_replica: f64,
        expert: usize,
    }
    impl Eq for Item {}
    impl PartialOrd for Item {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Item {
        fn cmp(&self, o: &Self) -> Ordering {
            self.per_replica
                .partial_cmp(&o.per_replica)
                .unwrap_or(Ordering::Equal)
                .then_with(|| o.expert.cmp(&self.expert))
        }
    }

    let mut counts = vec![1usize; e];
    let mut heap: BinaryHeap<Item> = loads
        .iter()
        .enumerate()
        .map(|(i, &l)| Item { per_replica: l, expert: i })
        .collect();
    for _ in e..total_slots {
        let top = heap.pop().expect("slots exceed placeable replicas");
        let ei = top.expert;
        counts[ei] += 1;
        if counts[ei] < max_count {
            heap.push(Item { per_replica: loads[ei] / counts[ei] as f64, expert: ei });
        }
    }
    counts
}

/// One random placement honoring `counts` and per-GPU slot budgets.
fn sample_placement(
    num_gpus: usize,
    counts: &[usize],
    slots_per_gpu: usize,
    rng: &mut Rng,
) -> Option<Placement> {
    let mut remaining = vec![slots_per_gpu; num_gpus];
    // place experts with most replicas first (hardest to fit)
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by_key(|&e| std::cmp::Reverse(counts[e]));

    let mut replicas = vec![Vec::new(); counts.len()];
    for &e in &order {
        let need = counts[e];
        // choose `need` distinct GPUs weighted by remaining capacity
        let mut chosen: Vec<usize> = Vec::with_capacity(need);
        for _ in 0..need {
            let weights: Vec<f64> = (0..num_gpus)
                .map(|g| {
                    if chosen.contains(&g) {
                        0.0
                    } else {
                        remaining[g] as f64
                    }
                })
                .collect();
            if weights.iter().sum::<f64>() <= 0.0 {
                return None;
            }
            let g = rng.weighted_index(&weights);
            chosen.push(g);
            remaining[g] -= 1;
        }
        chosen.sort_unstable();
        replicas[e] = chosen;
    }
    Some(Placement::from_replicas(num_gpus, replicas))
}

/// Full asymmetric placement: greedy counts + Monte-Carlo location search.
///
/// `samples` random placements are scored by Eq.-3 density under `loads`;
/// the densest-subgraph-minimal one wins.
pub fn asymmetric_placement(
    num_gpus: usize,
    loads: &[f64],
    slots_per_gpu: usize,
    samples: usize,
    rng: &mut Rng,
) -> Placement {
    let counts = greedy_replica_counts(loads, num_gpus * slots_per_gpu, num_gpus);
    let mut best: Option<(f64, Placement)> = None;
    let mut tries = 0usize;
    while tries < samples {
        tries += 1;
        let Some(p) = sample_placement(num_gpus, &counts, slots_per_gpu, rng) else {
            continue;
        };
        let d = max_induced_density(&p, loads, rng).density;
        if best.as_ref().map_or(true, |(bd, _)| d < *bd) {
            best = Some((d, p));
        }
    }
    let p = best.expect("no feasible placement sampled").1;
    p.validate().expect("Monte-Carlo search produced an invalid placement");
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::graph::{max_induced_density_exact, perfect_balance_bound};

    #[test]
    fn greedy_counts_proportional_to_load() {
        // loads 8:4:2:2 with 8 slots -> counts 4:2:1:1
        let counts = greedy_replica_counts(&[8.0, 4.0, 2.0, 2.0], 8, 8);
        assert_eq!(counts, vec![4, 2, 1, 1]);
    }

    #[test]
    fn greedy_counts_minimum_one_each() {
        let counts = greedy_replica_counts(&[100.0, 0.0, 0.0], 4, 8);
        assert_eq!(counts.iter().sum::<usize>(), 4);
        assert!(counts.iter().all(|&c| c >= 1));
        assert_eq!(counts[0], 2);
    }

    #[test]
    fn greedy_counts_equal_loads_equal_counts() {
        let counts = greedy_replica_counts(&[5.0; 8], 16, 8);
        assert_eq!(counts, vec![2; 8]);
    }

    #[test]
    fn greedy_counts_capped_at_gpu_count() {
        // a single dominating expert cannot exceed one replica per GPU
        let counts = greedy_replica_counts(&[1e6, 1.0, 1.0, 1.0], 10, 4);
        assert_eq!(counts[0], 4);
        assert_eq!(counts.iter().sum::<usize>(), 10);
    }

    #[test]
    fn asymmetric_beats_symmetric_under_heavy_skew() {
        // Zipf-like loads: symmetric uniform counts can't balance; the
        // asymmetric placement gives the hot expert more replicas
        let loads = vec![64.0, 8.0, 8.0, 8.0, 4.0, 4.0, 2.0, 2.0];
        let mut rng = Rng::new(42);
        let sym = crate::placement::cayley::cayley_graph_placement(4, 8);
        let asym = asymmetric_placement(4, &loads, 4, 200, &mut rng);
        let ds = max_induced_density_exact(&sym, &loads).density;
        let da = max_induced_density_exact(&asym, &loads).density;
        assert!(da <= ds + 1e-9, "asym {da} should be <= sym {ds}");
        // should get close to perfect balance
        let ideal = perfect_balance_bound(&loads, 4);
        assert!(da <= 1.35 * ideal, "asym {da} vs ideal {ideal}");
    }

    #[test]
    fn respects_slot_budget() {
        let loads = vec![10.0, 5.0, 3.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let mut rng = Rng::new(7);
        let p = asymmetric_placement(4, &loads, 4, 50, &mut rng);
        for g in 0..4 {
            assert!(p.slots_used(g) <= 4, "gpu {g} over budget");
        }
        let total: usize = (0..4).map(|g| p.slots_used(g)).sum();
        assert_eq!(total, 16);
        p.check_consistency().unwrap();
    }

    #[test]
    fn hot_expert_gets_replicas_everywhere() {
        let loads = vec![1000.0, 1.0, 1.0, 1.0];
        let counts = greedy_replica_counts(&loads, 8, 4);
        assert_eq!(counts[0], 4); // capped at GPU count
        let mut rng = Rng::new(3);
        let p = asymmetric_placement(4, &loads, 2, 100, &mut rng);
        assert_eq!(p.replica_count(0), 4, "hot expert spread: {:?}", p.replicas[0]);
    }
}
