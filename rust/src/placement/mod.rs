//! Expert placement: the long-term half of MicroEP's load balancing (§6).
//!
//! A placement assigns every expert replica to a GPU inside a MicroEP group.
//! Its quality is governed by the hypergraph abstraction of §6.1: vertices
//! are GPUs, each expert is a hyperedge over its EDP group, and the optimal
//! LPP-1 objective equals the **maximum induced subgraph density** (Eq. 3).
//!
//! * [`graph`] — density machinery: exact (subset enumeration) and
//!   heuristic (local search) maximum-density evaluators.
//! * [`cayley`] — symmetric placements from Cayley graphs (App. B),
//!   including the four worked examples.
//! * [`random`] — uniform random regular placements (the Fig. 7
//!   "MicroMoE (random)" arm).
//! * [`asymmetric`] — load-aware placements: greedy replica counts +
//!   Monte-Carlo location search (§6.3).

pub mod asymmetric;
pub mod cayley;
pub mod graph;
pub mod random;
pub mod sync;

use crate::topology::Topology;

/// An expert-replica placement inside one MicroEP group.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// GPUs in the MicroEP group.
    pub num_gpus: usize,
    /// Experts placed over the group.
    pub num_experts: usize,
    /// `replicas[e]` — GPUs hosting a replica of expert `e` (the EDP group
    /// of `e`), sorted, no duplicates.
    pub replicas: Vec<Vec<usize>>,
    /// `local_slots[g][s] = Some(e)` — expert occupying slot `s` on GPU `g`.
    /// The B.3 consistency restriction requires every replica of an expert
    /// to sit at the *same* slot index on all of its GPUs (deadlock-free
    /// DDP synchronization order).
    pub local_slots: Vec<Vec<Option<usize>>>,
}

impl Placement {
    /// Build from replica lists, assigning consistent local slot indices.
    ///
    /// Slot assignment is graph edge-coloring in disguise: experts sharing a
    /// GPU need different slots, and an expert needs one slot valid on all
    /// its GPUs. Greedy first-fit over experts (heaviest-degree first)
    /// extends the slot count past `slots_per_gpu` only when forced
    /// (Vizing's theorem allows Δ+1 in the worst case).
    pub fn from_replicas(num_gpus: usize, replicas: Vec<Vec<usize>>) -> Self {
        let num_experts = replicas.len();
        for (e, grp) in replicas.iter().enumerate() {
            assert!(!grp.is_empty(), "expert {e} has no replicas");
            let mut sorted = grp.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), grp.len(), "expert {e} has duplicate GPUs");
            assert!(*sorted.last().unwrap() < num_gpus, "expert {e} GPU out of range");
        }
        // order experts by degree (large EDP groups are hardest to place)
        let mut order: Vec<usize> = (0..num_experts).collect();
        order.sort_by_key(|&e| std::cmp::Reverse(replicas[e].len()));

        let mut local_slots: Vec<Vec<Option<usize>>> = vec![Vec::new(); num_gpus];
        for &e in &order {
            let grp = &replicas[e];
            let mut slot = 0usize;
            loop {
                let free = grp
                    .iter()
                    .all(|&g| local_slots[g].get(slot).copied().flatten().is_none());
                if free {
                    for &g in grp {
                        if local_slots[g].len() <= slot {
                            local_slots[g].resize(slot + 1, None);
                        }
                        local_slots[g][slot] = Some(e);
                    }
                    break;
                }
                slot += 1;
            }
        }
        let mut p = Placement { num_gpus, num_experts, replicas, local_slots };
        p.normalize_replicas();
        p
    }

    fn normalize_replicas(&mut self) {
        for grp in &mut self.replicas {
            grp.sort_unstable();
        }
    }

    /// EDP group of an expert.
    pub fn edp_group(&self, e: usize) -> &[usize] {
        &self.replicas[e]
    }

    /// Number of replicas of expert `e`.
    pub fn replica_count(&self, e: usize) -> usize {
        self.replicas[e].len()
    }

    /// Total replica slots used on GPU `g`.
    pub fn slots_used(&self, g: usize) -> usize {
        self.local_slots[g].iter().filter(|s| s.is_some()).count()
    }

    /// Maximum slot index in use plus one (the DDP sync depth).
    pub fn slot_depth(&self) -> usize {
        self.local_slots.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Whether GPU `g` hosts a replica of expert `e`.
    pub fn hosts(&self, g: usize, e: usize) -> bool {
        self.replicas[e].binary_search(&g).is_ok()
    }

    /// The slot index of expert `e` (identical on all its GPUs by B.3).
    pub fn slot_of(&self, e: usize) -> Option<usize> {
        let g = *self.replicas[e].first()?;
        self.local_slots[g].iter().position(|&s| s == Some(e))
    }

    /// Verify the B.3 consistency restriction and structural invariants.
    pub fn check_consistency(&self) -> Result<(), String> {
        for e in 0..self.num_experts {
            let slot = self
                .slot_of(e)
                .ok_or_else(|| format!("expert {e} missing from its first GPU"))?;
            for &g in &self.replicas[e] {
                if self.local_slots[g].get(slot).copied().flatten() != Some(e) {
                    return Err(format!(
                        "expert {e} slot {slot} inconsistent on GPU {g} (B.3 violated)"
                    ));
                }
            }
        }
        // every occupied slot belongs to an expert that lists that GPU
        for (g, slots) in self.local_slots.iter().enumerate() {
            for (s, &occ) in slots.iter().enumerate() {
                if let Some(e) = occ {
                    if !self.hosts(g, e) {
                        return Err(format!("slot ({g},{s}) holds non-resident expert {e}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Full structural validation: shape invariants plus the B.3 slot
    /// consistency of [`Placement::check_consistency`]. This is the gate
    /// every search/controller output passes through before a placement is
    /// handed to a scheduler — `from_replicas` establishes the invariants,
    /// `validate` proves an arbitrary (possibly hand-assembled or mutated)
    /// placement still satisfies them.
    pub fn validate(&self) -> Result<(), String> {
        if self.replicas.len() != self.num_experts {
            return Err(format!(
                "replicas has {} groups for {} experts",
                self.replicas.len(),
                self.num_experts
            ));
        }
        if self.local_slots.len() != self.num_gpus {
            return Err(format!(
                "local_slots has {} rows for {} GPUs",
                self.local_slots.len(),
                self.num_gpus
            ));
        }
        for (e, grp) in self.replicas.iter().enumerate() {
            if grp.is_empty() {
                return Err(format!("expert {e} has no replicas"));
            }
            if !grp.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("expert {e} replica group not sorted/deduped"));
            }
            if *grp.last().unwrap() >= self.num_gpus {
                return Err(format!("expert {e} replica GPU out of range"));
            }
        }
        // every replica must actually occupy a slot on its GPU (B.3 check
        // below then proves it is the *same* slot everywhere)
        for (e, grp) in self.replicas.iter().enumerate() {
            for &g in grp {
                if !self.local_slots[g].contains(&Some(e)) {
                    return Err(format!("expert {e} listed on GPU {g} but holds no slot"));
                }
            }
        }
        self.check_consistency()
    }

    /// Vanilla-EP placement for reference/baselines: expert `e` lives on EP
    /// rank `e / experts_per_gpu` of *every* EP group in the MicroEP scope —
    /// identical placement per EP group, so EDP groups never intersect
    /// (the Fig. 3b failure mode).
    pub fn vanilla_ep(topo: &Topology, num_experts: usize) -> Self {
        let num_gpus = topo.microep_group_size();
        let per_gpu = topo.experts_per_gpu(num_experts);
        let replicas = (0..num_experts)
            .map(|e| {
                let rank = e / per_gpu;
                (0..topo.d).map(|k| k * topo.ep_degree + rank).collect()
            })
            .collect();
        Placement::from_replicas(num_gpus, replicas)
    }

    /// Aggregate per-GPU load implied by replica loads `x[e][r]` (aligned
    /// with `replicas[e]` order).
    pub fn gpu_loads(&self, x: &[Vec<f64>]) -> Vec<f64> {
        let mut loads = vec![0.0; self.num_gpus];
        for (e, grp) in self.replicas.iter().enumerate() {
            for (r, &g) in grp.iter().enumerate() {
                loads[g] += x[e][r];
            }
        }
        loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure3c_placement() {
        // Figure 3c: 4 GPUs, 4 experts, d=2; EDP groups {0,3},{0,1},{1,2},{2,3}
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]],
        );
        assert_eq!(p.edp_group(0), &[0, 3]);
        assert!(p.hosts(0, 1));
        assert!(!p.hosts(2, 0));
        p.check_consistency().unwrap();
        // ring: 2 slots per GPU suffice
        assert_eq!(p.slot_depth(), 2);
        for g in 0..4 {
            assert_eq!(p.slots_used(g), 2);
        }
    }

    #[test]
    fn consistency_slot_identical_across_replicas() {
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![3, 0]],
        );
        for e in 0..4 {
            let slot = p.slot_of(e).unwrap();
            for &g in p.edp_group(e) {
                assert_eq!(p.local_slots[g][slot], Some(e));
            }
        }
    }

    #[test]
    fn vanilla_ep_identical_groups() {
        // DP=4, EP=2, d=2 -> 4 GPUs, 4 experts, 2 per GPU (Figure 3a/b)
        let topo = Topology::new(4, 2, 2, 8);
        let p = Placement::vanilla_ep(&topo, 4);
        // experts 0,1 on EP rank 0 (GPUs 0,2); experts 2,3 on rank 1 (1,3)
        assert_eq!(p.edp_group(0), &[0, 2]);
        assert_eq!(p.edp_group(1), &[0, 2]);
        assert_eq!(p.edp_group(2), &[1, 3]);
        assert_eq!(p.edp_group(3), &[1, 3]);
        p.check_consistency().unwrap();
    }

    #[test]
    fn gpu_loads_aggregation() {
        let p = Placement::from_replicas(3, vec![vec![0, 1], vec![1, 2]]);
        let loads = p.gpu_loads(&[vec![5.0, 3.0], vec![2.0, 4.0]]);
        assert_eq!(loads, vec![5.0, 5.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_gpu_rejected() {
        Placement::from_replicas(4, vec![vec![1, 1]]);
    }

    #[test]
    fn validate_accepts_constructed_and_rejects_mutated() {
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]],
        );
        p.validate().unwrap();

        // break B.3: move expert 0's replica on GPU 3 to a different slot
        let mut broken = p.clone();
        let s = broken.slot_of(0).unwrap();
        broken.local_slots[3][s] = None;
        broken.local_slots[3].push(Some(0));
        assert!(broken.validate().is_err(), "slot-inconsistent placement must fail");

        // break residency: a slot holding an expert not replicated there
        let mut ghost = p.clone();
        ghost.local_slots[0].push(Some(2));
        assert!(ghost.validate().is_err(), "non-resident occupant must fail");

        // break shape: unsorted replica group
        let mut unsorted = p.clone();
        unsorted.replicas[1] = vec![1, 0];
        assert!(unsorted.validate().is_err(), "unsorted group must fail");

        // break coverage: replica listed without any slot
        let mut missing = p;
        missing.replicas[2].push(3);
        assert!(missing.validate().is_err(), "slotless replica must fail");
    }

    #[test]
    fn greedy_slots_handle_overlap() {
        // star-ish pattern forcing slot growth on GPU 0
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 1], vec![0, 2], vec![0, 3], vec![1, 2]],
        );
        p.check_consistency().unwrap();
        assert_eq!(p.slots_used(0), 3);
    }
}
