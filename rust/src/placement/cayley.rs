//! Symmetric placements from Cayley graphs (§6.2, Appendix B).
//!
//! With no prior knowledge of expert loads, the best placement treats all
//! experts identically; Cayley graphs give vertex-transitive layouts whose
//! induced subgraphs cannot concentrate edges. We implement the paper's
//! four worked examples plus the general constructions they generalize to:
//!
//! * d = 2, E = G          → cycle (Example 1, ℤ_G with {±1})
//! * d = 2, 2E = G·deg     → circulant graphs ℤ_G with odd-offset
//!   generating sets; torus grids for square G (Example 2); ℤ2×ℤ4-style
//!   products (Example 3 falls out of the circulant family up to
//!   isomorphism — K4,4);
//! * deg ≥ G-1             → complete graph(s) + matchings (Example 4);
//! * d > 2                 → hyper-circulant: hyperedge {g, g+1, …, g+d-1}
//!   shifted around the ring (the natural hypergraph analogue).

use super::Placement;
use crate::topology::Topology;

/// Symmetric placement for `num_experts` experts over the MicroEP group of
/// `topo`, one replica set of `d` GPUs per expert, uniform replica counts.
///
/// Requires `num_experts * d == num_gpus * slots_per_gpu` slot conservation
/// (which holds whenever experts divide over the EP group).
pub fn symmetric_placement(topo: &Topology, num_experts: usize) -> Placement {
    let g = topo.microep_group_size();
    let d = topo.d;
    assert!(d >= 2, "MicroEP needs d >= 2 for intersecting EDP groups");
    if d == 2 {
        cayley_graph_placement(g, num_experts)
    } else {
        hyper_circulant(g, num_experts, d)
    }
}

/// d = 2 case: experts are edges of a degree-regular graph over GPUs.
///
/// degree k = 2·E / G must be integral. Construction:
/// * k ≤ G-1: circulant with generators {±1, ±2(odd steps)…} — for k = 2 a
///   cycle (Example 1); even k uses offsets 1..k/2; odd k additionally the
///   antipode G/2 (an involution, giving a perfect matching).
/// * k > G-1: stack ⌊k/(G-1)⌋ complete graphs then place the remaining
///   edges as circulant layers (Example 4's "complete graphs + matchings").
pub fn cayley_graph_placement(num_gpus: usize, num_experts: usize) -> Placement {
    let g = num_gpus;
    assert!(g >= 2);
    assert!(
        (2 * num_experts) % g == 0,
        "2E = {num_experts}·2 must be divisible by G = {g} for a regular graph"
    );
    let mut edges: Vec<[usize; 2]> = Vec::with_capacity(num_experts);
    let mut remaining = num_experts;

    // complete-graph layers (Example 4 generalization)
    let kg_edges = g * (g - 1) / 2;
    while remaining >= kg_edges && kg_edges > 0 {
        for a in 0..g {
            for b in (a + 1)..g {
                edges.push([a, b]);
            }
        }
        remaining -= kg_edges;
    }

    // circulant layers: offset o connects i -- i+o (G edges per layer); the
    // antipodal offset G/2 forms a perfect matching (G/2 edges). Offsets may
    // repeat across layers: experts are *hyperedges*, so parallel edges are
    // legal (two experts sharing an EDP group), exactly like Example 4's
    // K8 + extra matching.
    let mut offset = 1usize;
    while remaining >= g {
        // skip the antipode inside the cycling range for full layers
        if g % 2 == 0 && offset == g / 2 {
            offset = if g > 2 { offset % (g / 2 - 1) + 1 } else { 1 };
        }
        for i in 0..g {
            let j = (i + offset) % g;
            edges.push([i.min(j), i.max(j)]);
        }
        remaining -= g;
        offset = if g >= 4 { offset % (g / 2 - 1) + 1 } else { 1 };
    }
    if remaining > 0 {
        // 2E ≡ 0 (mod G) leaves exactly a half-layer: the antipodal matching
        assert!(
            g % 2 == 0 && remaining == g / 2,
            "leftover {remaining} edges on G={g} cannot form a regular layer"
        );
        for i in 0..g / 2 {
            edges.push([i, i + g / 2]);
        }
    }

    let replicas = edges.into_iter().map(|[a, b]| vec![a, b]).collect();
    Placement::from_replicas(g, replicas)
}

/// 2-D torus grid Cayley graph (Appendix B Example 2): G = side², degree 4,
/// E = 2·G. Generators {(0,±1), (±1,0)} over ℤ_side × ℤ_side.
pub fn torus_placement(side: usize) -> Placement {
    assert!(side >= 3, "torus needs side >= 3 for a simple graph");
    let g = side * side;
    let idx = |r: usize, c: usize| r * side + c;
    let mut replicas = Vec::with_capacity(2 * g);
    for r in 0..side {
        for c in 0..side {
            let right = idx(r, (c + 1) % side);
            let down = idx((r + 1) % side, c);
            let me = idx(r, c);
            replicas.push(vec![me.min(right), me.max(right)]);
            replicas.push(vec![me.min(down), me.max(down)]);
        }
    }
    Placement::from_replicas(g, replicas)
}

/// Appendix B Example 3: ℤ2 × ℤ4 with generators {(0,±1), (1,1), (1,-1)} —
/// 8 vertices, 16 edges, isomorphic to K4,4. Vertex (a,b) ↦ 4a + b.
pub fn z2xz4_placement() -> Placement {
    let idx = |a: usize, b: usize| 4 * a + (b % 4);
    let mut replicas = Vec::with_capacity(16);
    for a in 0..2usize {
        for b in 0..4usize {
            let me = idx(a, b);
            // (0,+1) and its inverse give the two 4-cycles; count each once
            let e1 = idx(a, b + 1);
            replicas.push(vec![me.min(e1), me.max(e1)]);
            // (1,+1): cross edge; generator set is inverse-closed, count once
            let e2 = idx(1 - a, b + 1);
            if a == 0 {
                replicas.push(vec![me.min(e2), me.max(e2)]);
            }
            let e3 = idx(1 - a, b + 3); // (1,-1)
            if a == 0 {
                replicas.push(vec![me.min(e3), me.max(e3)]);
            }
        }
    }
    Placement::from_replicas(8, replicas)
}

/// d > 2 hyper-circulant: expert i covers GPUs {s, s+1, …, s+d-1} (mod G)
/// with starts s spread uniformly; slot-conserving whenever E·d ≡ 0 mod G.
pub fn hyper_circulant(num_gpus: usize, num_experts: usize, d: usize) -> Placement {
    assert!(d >= 2 && d <= num_gpus);
    assert!(
        (num_experts * d) % num_gpus == 0,
        "replica slots E·d must divide over G GPUs"
    );
    let replicas = (0..num_experts)
        .map(|e| {
            // stride starts so edges wrap the ring multiple times at
            // different phases (layered circulant)
            let layer = e / num_gpus.min(num_experts);
            let start = (e % num_gpus) + layer; // phase shift per layer
            let mut grp: Vec<usize> =
                (0..d).map(|k| (start + k * (layer + 1)) % num_gpus).collect();
            grp.sort_unstable();
            grp.dedup();
            // if stride collided (rare), fall back to consecutive block
            if grp.len() < d {
                grp = (0..d).map(|k| (start + k) % num_gpus).collect();
                grp.sort_unstable();
            }
            grp
        })
        .collect();
    Placement::from_replicas(num_gpus, replicas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::graph::max_induced_density_exact;

    #[test]
    fn example1_cycle_8v_8e() {
        // Appendix B Example 1: 8 vertices, 8 edges -> cycle
        let p = cayley_graph_placement(8, 8);
        assert_eq!(p.num_experts, 8);
        for e in 0..8 {
            assert_eq!(p.replica_count(e), 2);
        }
        // every GPU hosts exactly 2 replicas
        for g in 0..8 {
            assert_eq!(p.slots_used(g), 2);
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn example2_torus_16v_32e() {
        let p = torus_placement(4);
        assert_eq!(p.num_gpus, 16);
        assert_eq!(p.num_experts, 32);
        for g in 0..16 {
            assert_eq!(p.slots_used(g), 4);
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn example3_z2z4_8v_16e() {
        let p = z2xz4_placement();
        assert_eq!(p.num_gpus, 8);
        assert_eq!(p.num_experts, 16);
        for g in 0..8 {
            assert_eq!(p.slots_used(g), 4, "gpu {g}");
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn example4_complete_plus_matching_8v_32e() {
        // 8 vertices, 32 edges = K8 (28) + 4 matching edges
        let p = cayley_graph_placement(8, 32);
        assert_eq!(p.num_experts, 32);
        for g in 0..8 {
            assert_eq!(p.slots_used(g), 8);
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn paper_testbed_32_experts_8_gpus() {
        // §7: DP=8, EP=4, d=2 -> 8 GPUs; 32 experts -> degree 8 circulant
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 32);
        assert_eq!(p.num_gpus, 8);
        for g in 0..8 {
            assert_eq!(p.slots_used(g), 8);
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn uniform_density_equals_average_on_cayley() {
        // vertex-transitivity: under uniform loads the max-density subset is
        // the whole group (no concentration)
        for p in [cayley_graph_placement(8, 16), torus_placement(3), z2xz4_placement()] {
            let loads = vec![6.0; p.num_experts];
            let r = max_induced_density_exact(&p, &loads);
            let avg = 6.0 * p.num_experts as f64 / p.num_gpus as f64;
            assert!((r.density - avg).abs() < 1e-9, "{r:?} vs avg {avg}");
            assert_eq!(r.subset.len(), p.num_gpus);
        }
    }

    #[test]
    fn cycle_beats_vanilla_under_skew() {
        // one hot expert: cycle spreads it over a pair; vanilla EP stacks
        // both replicas of every co-resident expert on the same EDP pair
        let topo = Topology::new(4, 2, 2, 8);
        let vanilla = Placement::vanilla_ep(&topo, 4);
        let cayley = cayley_graph_placement(4, 4);
        let loads = vec![40.0, 8.0, 8.0, 8.0];
        let dv = max_induced_density_exact(&vanilla, &loads).density;
        let dc = max_induced_density_exact(&cayley, &loads).density;
        assert!(dc < dv, "cayley {dc} should beat vanilla {dv}");
    }

    #[test]
    fn hyper_circulant_d3() {
        let p = hyper_circulant(6, 8, 3);
        assert_eq!(p.num_experts, 8);
        let total_slots: usize = (0..6).map(|g| p.slots_used(g)).sum();
        assert_eq!(total_slots, 24);
        for e in 0..8 {
            assert_eq!(p.replica_count(e), 3);
        }
        p.check_consistency().unwrap();
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn odd_edge_count_rejected() {
        cayley_graph_placement(8, 9); // 18 not divisible by 8... panics
    }
}
