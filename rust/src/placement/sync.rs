//! Synchronization-consistency simulation (Appendix B.3).
//!
//! DDP synchronizes expert parameters per local slot, in slot order, with a
//! blocking collective over each expert's EDP group. If replicas of one
//! expert sat at *different* local slot indices on different GPUs, two
//! experts could wait on each other's collectives — a deadlock. B.3's
//! restriction (identical local indices for all replicas) provably avoids
//! this; this module *executes* the sync schedule and checks.
//!
//! The simulator is deliberately literal: every GPU has a program = its
//! slot list; a collective fires only when every member GPU is parked on
//! it; we run to quiescence and report completion or the blocked cycle.

use super::Placement;

/// Outcome of simulating one full parameter-sync round.
#[derive(Clone, Debug, PartialEq)]
pub enum SyncOutcome {
    /// all collectives completed; total scheduling steps taken
    Completed { steps: usize },
    /// no progress possible: the set of (gpu, expert-waited-on) pairs
    Deadlocked { waiting: Vec<(usize, usize)> },
}

/// A per-GPU sync program: the experts to synchronize, in slot order.
/// `programs[g][k]` is the k-th collective GPU g participates in.
pub fn sync_programs(p: &Placement) -> Vec<Vec<usize>> {
    p.local_slots
        .iter()
        .map(|slots| slots.iter().filter_map(|&s| s).collect())
        .collect()
}

/// Simulate blocking in-order collectives. Generic over explicit programs
/// so tests can construct *inconsistent* ones (the failure B.3 prevents).
pub fn simulate_sync(programs: &[Vec<usize>], edp: &[Vec<usize>]) -> SyncOutcome {
    let g_count = programs.len();
    let mut pc = vec![0usize; g_count]; // program counter per GPU
    let mut steps = 0usize;
    loop {
        // which experts have every EDP member parked on them?
        let mut fired = false;
        for (e, group) in edp.iter().enumerate() {
            let ready = group.iter().all(|&g| {
                pc[g] < programs[g].len() && programs[g][pc[g]] == e
            });
            if ready {
                for &g in group {
                    pc[g] += 1;
                }
                steps += 1;
                fired = true;
            }
        }
        if !fired {
            let waiting: Vec<(usize, usize)> = (0..g_count)
                .filter(|&g| pc[g] < programs[g].len())
                .map(|g| (g, programs[g][pc[g]]))
                .collect();
            return if waiting.is_empty() {
                SyncOutcome::Completed { steps }
            } else {
                SyncOutcome::Deadlocked { waiting }
            };
        }
    }
}

/// Simulate the sync round implied by a placement's slot assignment.
pub fn simulate_placement_sync(p: &Placement) -> SyncOutcome {
    simulate_sync(&sync_programs(p), &p.replicas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::asymmetric::asymmetric_placement;
    use crate::placement::cayley::{cayley_graph_placement, symmetric_placement};
    use crate::placement::random::random_placement;
    use crate::prop::forall;
    use crate::topology::Topology;

    #[test]
    fn figure3c_ring_completes() {
        let p = crate::placement::Placement::from_replicas(
            4,
            vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]],
        );
        assert_eq!(simulate_placement_sync(&p), SyncOutcome::Completed { steps: 4 });
    }

    #[test]
    fn all_generators_deadlock_free() {
        forall("B.3 deadlock freedom", 60, |rng, case| {
            let p = match case % 3 {
                0 => cayley_graph_placement(8, 16),
                1 => random_placement(8, 16, 2, rng),
                _ => {
                    let loads: Vec<f64> =
                        (0..16).map(|_| rng.below(100) as f64 + 1.0).collect();
                    asymmetric_placement(8, &loads, 4, 10, rng)
                }
            };
            match simulate_placement_sync(&p) {
                SyncOutcome::Completed { steps } => {
                    assert_eq!(steps, p.num_experts, "every expert synced once");
                }
                SyncOutcome::Deadlocked { waiting } => {
                    panic!("B.3-consistent placement deadlocked: {waiting:?}");
                }
            }
        });
    }

    #[test]
    fn paper_testbed_placement_completes() {
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 32);
        assert!(matches!(
            simulate_placement_sync(&p),
            SyncOutcome::Completed { steps: 32 }
        ));
    }

    #[test]
    fn inconsistent_slots_deadlock() {
        // The B.3 counterexample: experts A(=0) and B(=1) both span GPUs
        // {0,1}, but GPU 0 orders A then B while GPU 1 orders B then A.
        // Each GPU blocks on its first collective forever.
        let programs = vec![vec![0usize, 1], vec![1usize, 0]];
        let edp = vec![vec![0, 1], vec![0, 1]];
        match simulate_sync(&programs, &edp) {
            SyncOutcome::Deadlocked { waiting } => {
                assert_eq!(waiting.len(), 2);
                assert!(waiting.contains(&(0, 0)) && waiting.contains(&(1, 1)));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn three_way_cycle_deadlocks() {
        // classic circular wait over three GPUs / three experts
        let programs = vec![vec![0usize, 2], vec![1usize, 0], vec![2usize, 1]];
        let edp = vec![vec![0, 1], vec![1, 2], vec![0, 2]];
        assert!(matches!(
            simulate_sync(&programs, &edp),
            SyncOutcome::Deadlocked { .. }
        ));
    }

    #[test]
    fn partial_programs_complete_when_orders_align() {
        // consistent global order even with gaps completes
        let programs = vec![vec![0usize, 1], vec![0usize], vec![1usize]];
        let edp = vec![vec![0, 1], vec![0, 2]];
        assert_eq!(
            simulate_sync(&programs, &edp),
            SyncOutcome::Completed { steps: 2 }
        );
    }

    #[test]
    fn random_slot_corruption_is_detected_or_harmless() {
        // fuzz: swapping two slots on ONE gpu either still completes (the
        // orders happen to stay compatible) or is reported as deadlock —
        // never hangs, never panics
        forall("corruption detection", 40, |rng, _| {
            let p = random_placement(6, 12, 2, rng);
            let mut programs = sync_programs(&p);
            let g = rng.below(6) as usize;
            if programs[g].len() >= 2 {
                let a = rng.below(programs[g].len() as u64) as usize;
                let b = rng.below(programs[g].len() as u64) as usize;
                programs[g].swap(a, b);
            }
            let _ = simulate_sync(&programs, &p.replicas); // must terminate
        });
    }
}
