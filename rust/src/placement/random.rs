//! Random regular placements — the "MicroMoE (random)" arm of Fig. 7.
//!
//! Each expert draws `d` distinct GPUs while keeping per-GPU replica counts
//! balanced (configuration-model style): a slot pool with `slots_per_gpu`
//! copies of each GPU is shuffled and consumed `d` at a time, resampling an
//! edge when it would collide (duplicate GPU inside one EDP group).

use super::Placement;
use crate::rng::Rng;

/// Random placement with uniform replica counts.
///
/// `num_experts * d` must equal `num_gpus * slots_per_gpu` for exact slot
/// conservation; `slots_per_gpu` is derived.
pub fn random_placement(num_gpus: usize, num_experts: usize, d: usize, rng: &mut Rng) -> Placement {
    assert!(d >= 2 && d <= num_gpus);
    assert!(
        (num_experts * d) % num_gpus == 0,
        "E·d = {} must divide over G = {num_gpus}",
        num_experts * d
    );
    let slots_per_gpu = num_experts * d / num_gpus;

    'outer: for _attempt in 0..200 {
        let mut pool: Vec<usize> = Vec::with_capacity(num_gpus * slots_per_gpu);
        for g in 0..num_gpus {
            pool.extend(std::iter::repeat(g).take(slots_per_gpu));
        }
        rng.shuffle(&mut pool);

        let mut replicas: Vec<Vec<usize>> = Vec::with_capacity(num_experts);
        for e in 0..num_experts {
            let start = e * d;
            let mut grp: Vec<usize> = pool[start..start + d].to_vec();
            grp.sort_unstable();
            let mut ok = true;
            for w in grp.windows(2) {
                if w[0] == w[1] {
                    ok = false;
                    break;
                }
            }
            if !ok {
                // local repair: swap a colliding element with a random pool
                // slot *at or after this edge* (earlier slots are already
                // consumed); a few tries, else restart the whole attempt
                let mut repaired = false;
                for _ in 0..50 {
                    let j = start + rng.below(d as u64) as usize;
                    let k = start + rng.below((pool.len() - start) as u64) as usize;
                    pool.swap(j, k);
                    let mut g2: Vec<usize> = pool[start..start + d].to_vec();
                    g2.sort_unstable();
                    if g2.windows(2).all(|w| w[0] != w[1]) {
                        grp = g2;
                        repaired = true;
                        break;
                    }
                }
                if !repaired {
                    continue 'outer;
                }
            }
            replicas.push(grp);
        }
        return Placement::from_replicas(num_gpus, replicas);
    }
    panic!("random_placement failed to find a collision-free assignment");
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_replica_counts() {
        let mut rng = Rng::new(1);
        let p = random_placement(8, 32, 2, &mut rng);
        for e in 0..32 {
            assert_eq!(p.replica_count(e), 2);
        }
        for g in 0..8 {
            assert_eq!(p.slots_used(g), 8, "gpu {g}");
        }
        p.check_consistency().unwrap();
    }

    #[test]
    fn no_duplicate_gpus_within_edp_group() {
        let mut rng = Rng::new(2);
        for seed in 0..20u64 {
            let mut r = Rng::new(seed);
            let p = random_placement(8, 16, 2, &mut r);
            for e in 0..16 {
                let grp = p.edp_group(e);
                assert!(grp.windows(2).all(|w| w[0] != w[1]));
            }
            let _ = &mut rng;
        }
    }

    #[test]
    fn d3_hyperedges() {
        let mut rng = Rng::new(3);
        let p = random_placement(6, 8, 3, &mut rng);
        for e in 0..8 {
            assert_eq!(p.replica_count(e), 3);
        }
        let total: usize = (0..6).map(|g| p.slots_used(g)).sum();
        assert_eq!(total, 24);
        p.check_consistency().unwrap();
    }

    #[test]
    fn different_seeds_give_different_placements() {
        let mut a = Rng::new(10);
        let mut b = Rng::new(11);
        let pa = random_placement(8, 16, 2, &mut a);
        let pb = random_placement(8, 16, 2, &mut b);
        assert_ne!(pa.replicas, pb.replicas);
    }

    #[test]
    fn same_seed_reproducible() {
        let pa = random_placement(8, 16, 2, &mut Rng::new(5));
        let pb = random_placement(8, 16, 2, &mut Rng::new(5));
        assert_eq!(pa.replicas, pb.replicas);
    }
}
