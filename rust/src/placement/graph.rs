//! Hypergraph density machinery (§6.1).
//!
//! Eq. 3:  m* = max over GPU subsets S of ( Σ_{e : EDP(e) ⊆ S} load_e ) / |S|.
//!
//! `max_induced_density_exact` enumerates all 2^|G|−1 subsets (fine for the
//! MicroEP group sizes the paper evaluates, |G| ≤ 24 with pruning);
//! `max_induced_density_approx` is a multi-start local search used inside
//! the Monte-Carlo placement loop where millions of evaluations occur.
//! Property tests assert exact == LP optimum (the Eq. 3 identity).

use super::Placement;
use crate::rng::Rng;

/// Result of a density search: the density and the witnessing GPU subset.
#[derive(Clone, Debug, PartialEq)]
pub struct DensityResult {
    /// Load density of the best subset.
    pub density: f64,
    /// The witnessing GPU subset.
    pub subset: Vec<usize>,
}

/// Exact maximum induced subgraph density by subset enumeration.
///
/// Complexity O(2^G · E); panics above 26 GPUs (use the approx variant).
pub fn max_induced_density_exact(p: &Placement, loads: &[f64]) -> DensityResult {
    let g = p.num_gpus;
    assert!(g <= 26, "exact density enumeration is 2^G; use approx for G={g}");
    assert_eq!(loads.len(), p.num_experts);

    // bitmask per expert
    let masks: Vec<u32> = p
        .replicas
        .iter()
        .map(|grp| grp.iter().fold(0u32, |m, &gg| m | (1 << gg)))
        .collect();

    let mut best = DensityResult { density: 0.0, subset: vec![] };
    for subset in 1u32..(1u32 << g) {
        let mut total = 0.0;
        for (e, &mask) in masks.iter().enumerate() {
            if mask & subset == mask {
                total += loads[e];
            }
        }
        let density = total / subset.count_ones() as f64;
        if density > best.density + 1e-12 {
            best = DensityResult { density, subset: mask_to_vec(subset) };
        }
    }
    best
}

fn mask_to_vec(mask: u32) -> Vec<usize> {
    (0..32).filter(|i| mask & (1 << i) != 0).collect()
}

/// Multi-start local-search approximation of the maximum induced density.
///
/// Moves: add a GPU / remove a GPU / swap, accepting improvements; restarts
/// from the heaviest single GPUs and random subsets. Always a lower bound
/// on the true maximum (it evaluates genuine subsets).
pub fn max_induced_density_approx(
    p: &Placement,
    loads: &[f64],
    rng: &mut Rng,
    restarts: usize,
) -> DensityResult {
    let g = p.num_gpus;
    assert_eq!(loads.len(), p.num_experts);
    let masks: Vec<u64> = p
        .replicas
        .iter()
        .map(|grp| grp.iter().fold(0u64, |m, &gg| m | (1 << gg)))
        .collect();

    let density_of = |subset: u64| -> f64 {
        if subset == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        for (e, &mask) in masks.iter().enumerate() {
            if mask & subset == mask {
                total += loads[e];
            }
        }
        total / subset.count_ones() as f64
    };

    // seed candidates: whole group, every single EDP group, heaviest GPU
    let mut seeds: Vec<u64> = vec![(1u64 << g) - 1];
    for mask in &masks {
        seeds.push(*mask);
    }
    for _ in 0..restarts {
        let mut s = 0u64;
        for i in 0..g {
            if rng.f64() < 0.5 {
                s |= 1 << i;
            }
        }
        if s != 0 {
            seeds.push(s);
        }
    }

    let mut best = DensityResult { density: 0.0, subset: vec![] };
    for seed in seeds {
        let mut cur = seed;
        let mut cur_d = density_of(cur);
        loop {
            let mut improved = false;
            for i in 0..g {
                let cand = cur ^ (1 << i); // toggle GPU i
                if cand == 0 {
                    continue;
                }
                let d = density_of(cand);
                if d > cur_d + 1e-12 {
                    cur = cand;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        if cur_d > best.density + 1e-12 {
            best = DensityResult {
                density: cur_d,
                subset: (0..g).filter(|i| cur & (1 << i) != 0).collect(),
            };
        }
    }
    best
}

/// Best available density evaluation: exact when cheap, else approx.
pub fn max_induced_density(p: &Placement, loads: &[f64], rng: &mut Rng) -> DensityResult {
    if p.num_gpus <= 16 {
        max_induced_density_exact(p, loads)
    } else {
        max_induced_density_approx(p, loads, rng, 32)
    }
}

/// The trivial lower bound on any schedule's makespan: total/|G| — perfect
/// balance. Eq. 3 meets this exactly when the full-group subset dominates.
pub fn perfect_balance_bound(loads: &[f64], num_gpus: usize) -> f64 {
    loads.iter().sum::<f64>() / num_gpus as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring4() -> Placement {
        Placement::from_replicas(4, vec![vec![0, 3], vec![0, 1], vec![1, 2], vec![2, 3]])
    }

    #[test]
    fn uniform_loads_density_is_average() {
        // ring with equal loads: every induced subgraph density <= total/G
        let p = ring4();
        let loads = vec![4.0; 4];
        let r = max_induced_density_exact(&p, &loads);
        assert!((r.density - 4.0).abs() < 1e-9);
        assert_eq!(r.subset.len(), 4);
    }

    #[test]
    fn figure3c_example_is_perfectly_balanced() {
        // Figure 3c loads: expert 0: 4, expert 1: 6, expert 2: 6, expert 3: 8
        // = 24 total over 4 GPUs -> paper says all GPU loads equal 6.
        let p = ring4();
        let loads = vec![4.0, 6.0, 6.0, 8.0];
        let r = max_induced_density_exact(&p, &loads);
        assert!((r.density - 6.0).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn hot_edge_dominates() {
        // one expert with extreme load: density = load/|EDP| on its own pair
        let p = ring4();
        let loads = vec![100.0, 0.0, 0.0, 0.0];
        let r = max_induced_density_exact(&p, &loads);
        assert!((r.density - 50.0).abs() < 1e-9);
        assert_eq!(r.subset, vec![0, 3]);
    }

    #[test]
    fn figure5_example() {
        // Figure 5: 4 GPUs; expert 0 on {0,3} load m-contributing, experts
        // 1,3 partially intersect Gmax={0,3}. Check a concrete instance:
        // loads chosen so Gmax = {0,3}.
        let p = Placement::from_replicas(
            4,
            vec![vec![0, 3], vec![0, 1], vec![2, 3], vec![1, 2]],
        );
        let loads = vec![20.0, 2.0, 2.0, 2.0];
        let r = max_induced_density_exact(&p, &loads);
        assert_eq!(r.subset, vec![0, 3]);
        assert!((r.density - 10.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_edp_groups_worst_case() {
        // vanilla-EP-like: both experts confined to {0,1}; GPU 2,3 idle-ish
        let p = Placement::from_replicas(4, vec![vec![0, 1], vec![0, 1]]);
        let loads = vec![10.0, 10.0];
        let r = max_induced_density_exact(&p, &loads);
        assert!((r.density - 10.0).abs() < 1e-9);
        assert_eq!(r.subset, vec![0, 1]);
    }

    #[test]
    fn approx_matches_exact_on_small_graphs() {
        let mut rng = Rng::new(99);
        for seed in 0..20 {
            let mut r2 = Rng::new(seed);
            let g = 6 + (seed as usize % 4);
            let e = 2 * g;
            let replicas: Vec<Vec<usize>> = (0..e)
                .map(|_| {
                    let a = r2.below(g as u64) as usize;
                    let mut b = r2.below(g as u64) as usize;
                    if b == a {
                        b = (a + 1) % g;
                    }
                    let mut v = vec![a, b];
                    v.sort_unstable();
                    v
                })
                .collect();
            let p = Placement::from_replicas(g, replicas);
            let loads: Vec<f64> = (0..e).map(|_| r2.below(50) as f64).collect();
            let exact = max_induced_density_exact(&p, &loads);
            let approx = max_induced_density_approx(&p, &loads, &mut rng, 16);
            assert!(
                approx.density <= exact.density + 1e-9,
                "approx exceeded exact"
            );
            assert!(
                approx.density >= 0.95 * exact.density - 1e-9,
                "seed {seed}: approx {} far below exact {}",
                approx.density,
                exact.density
            );
        }
    }

    #[test]
    fn density_lower_bounded_by_perfect_balance() {
        let p = ring4();
        let loads = vec![3.0, 9.0, 1.0, 7.0];
        let r = max_induced_density_exact(&p, &loads);
        assert!(r.density >= perfect_balance_bound(&loads, 4) - 1e-9);
    }
}
