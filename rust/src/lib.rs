//! # MicroMoE — fine-grained MoE load balancing with LP token scheduling
//!
//! Reproduction of *"MicroMoE: Fine-grained Load Balancing for
//! Mixture-of-Experts with Token Scheduling"* (a.k.a. *"Fine-grained MoE
//! Load Balancing with Linear Programming"*, CS.DC 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: per-micro-batch
//!   token scheduling via linear programming ([`scheduler`]), expert
//!   placement theory ([`placement`]), adaptive replacement ([`adaptive`])
//!   with its two-timescale placement controller ([`control`]),
//!   plus every substrate the paper depends on (LP solver [`lp`], cluster
//!   model [`cluster`], baselines [`baselines`], workloads [`workload`]).
//!   The public surface is the step-driven [`balancer::Balancer`] trait and
//!   the [`balancer::MoeSession`] facade, which run every policy —
//!   MicroMoE's LPP scheduling (barrier / pipelined / speculative engine)
//!   and all baselines — through one loop, selected by name via
//!   [`config::PolicySpec`].
//! * **Layer 2/1 (python/, build-time only)** — JAX GPT-MoE train step and
//!   Pallas grouped-FFN kernels, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from rust through PJRT ([`runtime`]).
//!
//! See README.md for the figure→bench mapping and docs/ARCHITECTURE.md for
//! the token-flow walkthrough (workload → scheduler → lp → cluster).
#![warn(missing_docs)]

pub mod adaptive;
pub mod balancer;
pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod control;
pub mod engine;
pub mod faults;
pub mod lp;
pub mod moe;
pub mod obs;
pub mod placement;
pub mod prop;
pub mod rng;
/// PJRT/XLA-backed artifact execution — needs the image's `xla` bindings;
/// gated so the default build stays dependency-light.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod scheduler;
pub mod ser;
pub mod serving;
pub mod stats;
pub mod topology;
/// e2e PJRT trainer (drives [`runtime`]); gated with it.
#[cfg(feature = "xla")]
pub mod train;
pub mod workload;

/// Crate version (from Cargo metadata).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
